//! A single dynamic shard: frozen snapshot + small mutable delta.
//!
//! Mutations follow an LSM-lite discipline so readers can probe without
//! holding any lock for the duration of a query:
//!
//! * **Frozen** — an immutable, `Arc`-shared generation holding the bulk
//!   of the shard: id/code arrays plus a bucket map from code to
//!   positions. Readers clone the `Arc` (one refcount bump) and then work
//!   entirely on their private snapshot.
//! * **Delta** — recent inserts (append-only arrays + bucket map) and a
//!   set of ids removed from the frozen generation. Kept small by
//!   compaction, so cloning it into a [`ShardView`] is cheap.
//! * **compact()** — merges delta into a fresh `Frozen`, swaps the `Arc`,
//!   bumps the shard epoch and clears the delta. Writers briefly block on
//!   one another (and on compaction) via the delta mutex; readers holding
//!   an older view are untouched — they keep the previous epoch's `Arc`
//!   until they drop it.
//!
//! Lock ordering is always delta → frozen, and the frozen mutex is only
//! ever held to clone or swap the `Arc`, so no lock is held across any
//! O(n) work that a reader could observe.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::data::FeatureStore;
use crate::hash::fasthash::CodeMap;
use crate::linalg::nrm2;
use crate::table::{with_scratch, QueryHit, QueryScratch};

/// Immutable generation of a shard.
pub(crate) struct Frozen {
    pub(crate) ids: Vec<u32>,
    pub(crate) codes: Vec<u64>,
    /// code → positions into `ids`/`codes`
    buckets: CodeMap<Vec<u32>>,
    /// id (widened to the u64 key domain) → position
    pos_of: CodeMap<u32>,
}

impl Frozen {
    fn empty() -> Self {
        Frozen {
            ids: Vec::new(),
            codes: Vec::new(),
            buckets: CodeMap::default(),
            pos_of: CodeMap::default(),
        }
    }

    fn build(entries: Vec<(u32, u64)>) -> Self {
        let mut f = Frozen {
            ids: Vec::with_capacity(entries.len()),
            codes: Vec::with_capacity(entries.len()),
            buckets: CodeMap::default(),
            pos_of: CodeMap::default(),
        };
        for (id, code) in entries {
            let pos = f.ids.len() as u32;
            f.ids.push(id);
            f.codes.push(code);
            f.buckets.entry(code).or_default().push(pos);
            f.pos_of.insert(id as u64, pos);
        }
        f
    }

    fn contains(&self, id: u32) -> bool {
        self.pos_of.contains_key(&(id as u64))
    }
}

/// Mutable tail of a shard since the last compaction.
struct Delta {
    ids: Vec<u32>,
    codes: Vec<u64>,
    /// false ⇒ slot superseded (upsert) or removed
    live: Vec<bool>,
    live_count: usize,
    buckets: CodeMap<Vec<u32>>,
    /// id → newest delta position
    pos_of: CodeMap<u32>,
    /// ids whose frozen entry is dead (removed or superseded)
    removed_frozen: HashSet<u32>,
}

impl Delta {
    fn empty() -> Self {
        Delta {
            ids: Vec::new(),
            codes: Vec::new(),
            live: Vec::new(),
            live_count: 0,
            buckets: CodeMap::default(),
            pos_of: CodeMap::default(),
            removed_frozen: HashSet::new(),
        }
    }
}

/// The live (id, code) set of a shard: frozen entries not tombstoned by
/// the delta, then the delta's live slots. The single source of truth for
/// both compaction and snapshot persistence — keep the visibility rules
/// in one place.
fn merge_live(frozen: &Frozen, d: &Delta) -> Vec<(u32, u64)> {
    let mut out = Vec::with_capacity(frozen.ids.len() + d.live_count);
    for (i, &id) in frozen.ids.iter().enumerate() {
        if !d.removed_frozen.contains(&id) {
            out.push((id, frozen.codes[i]));
        }
    }
    for (i, &id) in d.ids.iter().enumerate() {
        if d.live[i] {
            out.push((id, d.codes[i]));
        }
    }
    out
}

/// One shard of the online index.
pub struct Shard {
    epoch: AtomicU64,
    frozen: Mutex<Arc<Frozen>>,
    delta: Mutex<Delta>,
}

impl Default for Shard {
    fn default() -> Self {
        Self::new()
    }
}

impl Shard {
    pub fn new() -> Self {
        Shard {
            epoch: AtomicU64::new(0),
            frozen: Mutex::new(Arc::new(Frozen::empty())),
            delta: Mutex::new(Delta::empty()),
        }
    }

    /// Compactions performed so far — the version a [`ShardView`] carries.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    fn frozen_arc(&self) -> Arc<Frozen> {
        self.frozen.lock().unwrap().clone()
    }

    /// Insert (or upsert) `id` with hash `code`.
    pub fn insert(&self, id: u32, code: u64) {
        let mut d = self.delta.lock().unwrap();
        let prev = d.pos_of.get(&(id as u64)).copied();
        if let Some(pos) = prev {
            if d.live[pos as usize] {
                d.live[pos as usize] = false;
                d.live_count -= 1;
            }
        } else if self.frozen_arc().contains(id) {
            // only a delta miss needs to consult (and possibly tombstone)
            // the frozen generation — delta hits skip the frozen lock
            d.removed_frozen.insert(id);
        }
        let pos = d.ids.len() as u32;
        d.ids.push(id);
        d.codes.push(code);
        d.live.push(true);
        d.live_count += 1;
        d.pos_of.insert(id as u64, pos);
        d.buckets.entry(code).or_default().push(pos);
    }

    /// Remove `id`; returns whether it was present and live.
    pub fn remove(&self, id: u32) -> bool {
        let mut d = self.delta.lock().unwrap();
        if let Some(pos) = d.pos_of.get(&(id as u64)).copied() {
            let pos = pos as usize;
            if d.live[pos] {
                d.live[pos] = false;
                d.live_count -= 1;
                return true;
            }
            return false; // already removed (a dead slot masks any frozen entry)
        }
        if self.frozen_arc().contains(id) && d.removed_frozen.insert(id) {
            return true;
        }
        false
    }

    /// Whether `id` is currently live.
    pub fn contains(&self, id: u32) -> bool {
        let d = self.delta.lock().unwrap();
        if let Some(&pos) = d.pos_of.get(&(id as u64)) {
            return d.live[pos as usize];
        }
        let frozen = self.frozen_arc();
        frozen.contains(id) && !d.removed_frozen.contains(&id)
    }

    /// Live points in this shard.
    pub fn len(&self) -> usize {
        let d = self.delta.lock().unwrap();
        let frozen = self.frozen_arc();
        let removed = d.removed_frozen.iter().filter(|&&id| frozen.contains(id)).count();
        frozen.ids.len() - removed + d.live_count
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Delta slots (live + dead) — the quantity compaction bounds.
    pub fn delta_len(&self) -> usize {
        self.delta.lock().unwrap().ids.len()
    }

    /// Points in the frozen generation (before delta/removals).
    pub fn frozen_len(&self) -> usize {
        self.frozen_arc().ids.len()
    }

    /// Live (id, code) pairs, merged across frozen and delta — the payload
    /// a persisted snapshot stores.
    pub fn live_entries(&self) -> Vec<(u32, u64)> {
        let d = self.delta.lock().unwrap();
        let frozen = self.frozen_arc();
        merge_live(&frozen, &d)
    }

    /// Delta slots plus frozen tombstones — the total mutation backlog the
    /// next compaction will fold in. This (not just `delta_len`) is what
    /// auto-compaction thresholds, so remove-heavy workloads also get
    /// compacted and view snapshots stay cheap to clone.
    pub fn pending_len(&self) -> usize {
        let d = self.delta.lock().unwrap();
        d.ids.len() + d.removed_frozen.len()
    }

    /// Merge the delta into a fresh frozen generation and bump the epoch.
    /// Readers holding an older [`ShardView`] are unaffected.
    pub fn compact(&self) {
        let mut d = self.delta.lock().unwrap();
        if d.ids.is_empty() && d.removed_frozen.is_empty() {
            return;
        }
        let frozen = self.frozen_arc();
        let entries = merge_live(&frozen, &d);
        *self.frozen.lock().unwrap() = Arc::new(Frozen::build(entries));
        self.epoch.fetch_add(1, Ordering::AcqRel);
        *d = Delta::empty();
    }

    /// Epoch-consistent read snapshot: shares the frozen generation by
    /// `Arc` and clones the (compaction-bounded) delta, so probing runs
    /// without touching the shard's locks again.
    pub fn view(&self) -> ShardView {
        let d = self.delta.lock().unwrap();
        let frozen = self.frozen_arc();
        ShardView {
            epoch: self.epoch.load(Ordering::Acquire),
            frozen,
            delta_ids: d.ids.clone(),
            delta_codes: d.codes.clone(),
            delta_live: d.live.clone(),
            delta_buckets: d.buckets.clone(),
            removed_frozen: d.removed_frozen.clone(),
        }
    }

    /// Approximate heap footprint in bytes (capacities, not lengths).
    /// Bucket maps use the accounting shared with the static tables
    /// ([`crate::hash::fasthash::bucket_map_bytes`]).
    pub fn memory_bytes(&self) -> usize {
        let d = self.delta.lock().unwrap();
        let frozen = self.frozen_arc();
        let map_entry = |ksz: usize, vsz: usize, cap: usize| cap * (ksz + vsz + 1);
        let bucket_bytes = crate::hash::fasthash::bucket_map_bytes;
        frozen.ids.capacity() * 4
            + frozen.codes.capacity() * 8
            + bucket_bytes(&frozen.buckets)
            + map_entry(8, 4, frozen.pos_of.capacity())
            + d.ids.capacity() * 4
            + d.codes.capacity() * 8
            + d.live.capacity()
            + bucket_bytes(&d.buckets)
            + map_entry(8, 4, d.pos_of.capacity())
            + d.removed_frozen.capacity() * 5
    }
}

/// A consistent point-in-time view of one shard.
pub struct ShardView {
    /// shard compaction epoch this view was taken at
    pub epoch: u64,
    frozen: Arc<Frozen>,
    delta_ids: Vec<u32>,
    delta_codes: Vec<u64>,
    delta_live: Vec<bool>,
    delta_buckets: CodeMap<Vec<u32>>,
    removed_frozen: HashSet<u32>,
}

impl ShardView {
    /// Append the live ids hashed to bucket `code`; returns how many were
    /// appended.
    pub fn probe_into(&self, code: u64, out: &mut Vec<u32>) -> usize {
        let before = out.len();
        if let Some(ps) = self.frozen.buckets.get(&code) {
            for &p in ps {
                let id = self.frozen.ids[p as usize];
                if !self.removed_frozen.contains(&id) {
                    out.push(id);
                }
            }
        }
        if let Some(ps) = self.delta_buckets.get(&code) {
            for &p in ps {
                if self.delta_live[p as usize] {
                    out.push(self.delta_ids[p as usize]);
                }
            }
        }
        out.len() - before
    }

    /// Shard-local probe sequence: visit `lookup ^ mask` for each planned
    /// flip mask, margin-rank the live candidates against `w`, stop early
    /// once `top` candidates have been ranked. The partial [`QueryHit`]s
    /// of several shards merge with [`crate::online::merge_hits`].
    pub fn query(
        &self,
        masks: &[u64],
        lookup: u64,
        w: &[f32],
        feats: &FeatureStore,
        top: usize,
        eligible: impl Fn(usize) -> bool,
    ) -> QueryHit {
        with_scratch(|s| self.query_with(masks, lookup, w, feats, top, eligible, s))
    }

    /// [`Self::query`] with caller-owned scratch for the per-mask
    /// candidate gather — router worker loops own one scratch per thread
    /// so the probe path allocates nothing per query. Hits are identical.
    #[allow(clippy::too_many_arguments)]
    pub fn query_with(
        &self,
        masks: &[u64],
        lookup: u64,
        w: &[f32],
        feats: &FeatureStore,
        top: usize,
        eligible: impl Fn(usize) -> bool,
        scratch: &mut QueryScratch,
    ) -> QueryHit {
        let w_norm = nrm2(w);
        let cand: &mut Vec<u32> = &mut scratch.cand;
        cand.clear();
        let mut best: Option<(usize, f32)> = None;
        let mut scanned = 0usize;
        let mut probed = 0usize;
        let mut any = false;
        for &mask in masks {
            probed += 1;
            self.probe_into(lookup ^ mask, cand);
            if !cand.is_empty() {
                any = true;
                for &id in cand.iter() {
                    let id = id as usize;
                    if !eligible(id) {
                        continue;
                    }
                    scanned += 1;
                    let m = crate::linalg::margin_feat(feats.row(id), w, w_norm);
                    if best.map_or(true, |(_, bm)| m < bm) {
                        best = Some((id, m));
                    }
                }
                cand.clear();
            }
            if scanned >= top {
                break;
            }
        }
        QueryHit { best, scanned, probed, nonempty: any }
    }

    /// Like [`Self::query`], but append every margin-ranked candidate to
    /// `out` instead of keeping only the minimum — the shard-local half
    /// of the paper's "short list L" protocol. The same per-shard `top`
    /// early-exit applies; the caller merges and truncates across shards
    /// ([`crate::online::ShardedIndex::query_topk`]).
    #[allow(clippy::too_many_arguments)]
    pub fn query_topk(
        &self,
        masks: &[u64],
        lookup: u64,
        w: &[f32],
        feats: &FeatureStore,
        top: usize,
        eligible: impl Fn(usize) -> bool,
        out: &mut Vec<(usize, f32)>,
    ) {
        with_scratch(|s| self.query_topk_with(masks, lookup, w, feats, top, eligible, out, s))
    }

    /// [`Self::query_topk`] with caller-owned gather scratch; the
    /// appended short list is identical.
    #[allow(clippy::too_many_arguments)]
    pub fn query_topk_with(
        &self,
        masks: &[u64],
        lookup: u64,
        w: &[f32],
        feats: &FeatureStore,
        top: usize,
        eligible: impl Fn(usize) -> bool,
        out: &mut Vec<(usize, f32)>,
        scratch: &mut QueryScratch,
    ) {
        let w_norm = nrm2(w);
        let cand: &mut Vec<u32> = &mut scratch.cand;
        cand.clear();
        let mut scanned = 0usize;
        for &mask in masks {
            self.probe_into(lookup ^ mask, cand);
            for &id in cand.iter() {
                let id = id as usize;
                if !eligible(id) {
                    continue;
                }
                scanned += 1;
                out.push((id, crate::linalg::margin_feat(feats.row(id), w, w_norm)));
            }
            cand.clear();
            if scanned >= top {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_contains_len() {
        let s = Shard::new();
        assert!(s.is_empty());
        s.insert(3, 0b101);
        s.insert(9, 0b101);
        s.insert(4, 0b010);
        assert_eq!(s.len(), 3);
        assert!(s.contains(3) && s.contains(9) && s.contains(4));
        assert!(s.remove(9));
        assert!(!s.remove(9), "double remove is a no-op");
        assert!(!s.contains(9));
        assert_eq!(s.len(), 2);
        assert!(!s.remove(1000), "absent id");
    }

    #[test]
    fn view_filters_removed_and_sees_delta() {
        let s = Shard::new();
        for id in 0..10u32 {
            s.insert(id, 0xAB);
        }
        s.compact();
        assert_eq!(s.epoch(), 1);
        s.remove(4); // frozen removal
        s.insert(77, 0xAB); // delta insert
        s.insert(78, 0xCD);
        s.remove(78); // delta removal
        let v = s.view();
        let mut got = Vec::new();
        v.probe_into(0xAB, &mut got);
        got.sort_unstable();
        let want: Vec<u32> = (0..10).filter(|&i| i != 4).chain([77]).collect();
        assert_eq!(got, want);
        let mut none = Vec::new();
        assert_eq!(v.probe_into(0xCD, &mut none), 0, "removed delta entry invisible");
    }

    #[test]
    fn upsert_changes_code_without_duplicates() {
        let s = Shard::new();
        s.insert(5, 0b001);
        s.compact();
        s.insert(5, 0b110); // upsert with a new code
        assert_eq!(s.len(), 1);
        let v = s.view();
        let mut old = Vec::new();
        assert_eq!(v.probe_into(0b001, &mut old), 0, "old code masked");
        let mut new = Vec::new();
        assert_eq!(v.probe_into(0b110, &mut new), 1);
        assert_eq!(new, vec![5]);
        s.compact();
        assert_eq!(s.len(), 1);
        let mut after = Vec::new();
        assert_eq!(s.view().probe_into(0b110, &mut after), 1);
    }

    #[test]
    fn compaction_preserves_live_set_and_bumps_epoch() {
        let s = Shard::new();
        for id in 0..100u32 {
            s.insert(id, (id % 7) as u64);
        }
        for id in (0..100u32).step_by(3) {
            s.remove(id);
        }
        let before: Vec<(u32, u64)> = {
            let mut e = s.live_entries();
            e.sort_unstable();
            e
        };
        let e0 = s.epoch();
        s.compact();
        assert_eq!(s.epoch(), e0 + 1);
        assert_eq!(s.delta_len(), 0);
        let mut after = s.live_entries();
        after.sort_unstable();
        assert_eq!(before, after);
        // no-op compaction does not bump the epoch
        s.compact();
        assert_eq!(s.epoch(), e0 + 1);
    }

    #[test]
    fn old_views_survive_concurrent_compaction() {
        let s = Shard::new();
        for id in 0..50u32 {
            s.insert(id, 1);
        }
        let v = s.view();
        s.remove(0);
        s.compact();
        s.remove(1);
        s.compact();
        // the old view still answers from its epoch
        let mut got = Vec::new();
        v.probe_into(1, &mut got);
        assert_eq!(got.len(), 50);
        assert_eq!(v.epoch, 0);
        let mut now = Vec::new();
        s.view().probe_into(1, &mut now);
        assert_eq!(now.len(), 48);
    }

    #[test]
    fn memory_bytes_grows_with_content() {
        let s = Shard::new();
        let empty = s.memory_bytes();
        for id in 0..1000u32 {
            s.insert(id, (id as u64) & 0xF);
        }
        s.compact();
        assert!(s.memory_bytes() > empty + 1000 * 12, "codes+ids payload counted");
    }
}
