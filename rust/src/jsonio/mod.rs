//! Minimal JSON reader/writer (the vendored registry has no serde).
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`) written by
//! `python/compile/aot.py`, for the `results/*.json` experiment records, and
//! as the wire format of the HTTP serving front-end (`crate::server`).
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the BMP.
//!
//! The parser is total: every failure — including truncated escapes, invalid
//! UTF-8 (via [`Json::parse_bytes`]) and nesting deeper than [`MAX_DEPTH`] —
//! is a [`JsonError`], never a panic, so untrusted network payloads can be
//! fed to it directly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Maximum container nesting the parser accepts. The recursive-descent
/// parser spends one stack frame per level, so the cap is what keeps a
/// `[[[[…` payload from overflowing the stack of a serving thread.
pub const MAX_DEPTH: usize = 128;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Parse a raw byte payload (e.g. an HTTP request body). Invalid UTF-8
    /// is a [`JsonError`] at the first bad byte, not a panic — the entry
    /// point network handlers should use.
    pub fn parse_bytes(b: &[u8]) -> Result<Json, JsonError> {
        let s = std::str::from_utf8(b)
            .map_err(|e| JsonError { pos: e.valid_up_to(), msg: "invalid utf-8".to_string() })?;
        Json::parse(s)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // `-0.0` must stay `-0` (not collapse to the integer `0`)
                // so float payloads round-trip bit-exactly over the wire;
                // non-finite values have no JSON spelling — emit null
                // rather than the unparseable `NaN`/`inf`
                let neg_zero = *n == 0.0 && n.is_sign_negative();
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 && !neg_zero {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors.
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
    /// current container nesting, capped at [`MAX_DEPTH`]
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        self.enter()?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        self.enter()?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(c0) => {
                    // consume one UTF-8 char: sequence length from the lead
                    // byte, then validate just that window (O(1) per char —
                    // no panic on a truncated or malformed tail)
                    let len = match c0 {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        0xf0..=0xf7 => 4,
                        _ => return Err(self.err("invalid utf-8")),
                    };
                    if self.pos + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let ch = std::str::from_utf8(&self.b[self.pos..self.pos + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(ch);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "e": "hi\n\"x\""}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "a": [1], "f": false}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(v.get("f").unwrap().as_bool(), Some(false));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"abc", "[1] x"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn integer_formatting() {
        let v = Json::Num(42.0);
        assert_eq!(v.to_string_compact(), "42");
        let v = Json::Num(1.5);
        assert_eq!(v.to_string_compact(), "1.5");
    }

    #[test]
    fn negative_zero_roundtrips() {
        let v = Json::Num(-0.0);
        assert_eq!(v.to_string_compact(), "-0");
        let back = Json::parse("-0").unwrap().as_f64().unwrap();
        assert!(back == 0.0 && back.is_sign_negative());
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let doc = Json::Arr(vec![Json::Num(v)]).to_string_compact();
            assert_eq!(doc, "[null]", "no JSON spelling for {v}");
            assert!(Json::parse(&doc).is_ok(), "output must stay parseable");
        }
    }

    #[test]
    fn builder_obj() {
        let v = obj(vec![("k", Json::from(1usize)), ("s", Json::from("v"))]);
        assert_eq!(v.to_string_compact(), r#"{"k":1,"s":"v"}"#);
    }

    #[test]
    fn truncated_escapes_are_errors_not_panics() {
        // every prefix of a valid document must parse or error — never panic
        for bad in [
            "\"\\",        // string ends inside an escape
            "\"\\u",       // \u with no hex digits
            "\"\\u00",     // \u with too few hex digits
            "\"\\u12",     // ditto
            "\"\\q\"",     // unknown escape
            "\"abc\\u12g4\"", // non-hex in the escape
        ] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
        let full = r#"{"w": [1.5, -2e3], "s": "a\u00e9b"}"#;
        for cut in 0..full.len() {
            let _ = Json::parse(&full[..cut]); // must not panic
        }
    }

    #[test]
    fn parse_bytes_rejects_invalid_utf8() {
        assert!(Json::parse_bytes(br#"{"a": 1}"#).is_ok());
        // 0xff is never valid UTF-8; error position points at the bad byte
        let err = Json::parse_bytes(b"\"ab\xff\"").unwrap_err();
        assert_eq!(err.pos, 3);
        // lead byte promising a continuation that never comes
        assert!(Json::parse_bytes(b"\"\xc3").is_err());
    }

    #[test]
    fn deep_nesting_is_bounded() {
        // one level under the cap parses...
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
        // ...the cap itself errors instead of overflowing the stack
        let deep = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(Json::parse(&deep).is_err());
        let very_deep = "[".repeat(100_000);
        assert!(Json::parse(&very_deep).is_err());
        let mixed = format!("{}{}", r#"{"a":"#.repeat(MAX_DEPTH + 1), "1");
        let err = Json::parse(&mixed).unwrap_err();
        assert!(err.msg.contains("deep"), "objects count toward the depth cap: {err}");
    }

    #[test]
    fn siblings_do_not_accumulate_depth() {
        // depth is nesting, not total container count: a long flat array
        // of small objects must parse
        let flat = format!("[{}{{}}]", "{},".repeat(1000));
        assert!(Json::parse(&flat).is_ok());
    }
}
