//! Minimal JSON reader/writer (the vendored registry has no serde).
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`) written by
//! `python/compile/aot.py`, and for the `results/*.json` experiment records.
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors.
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "e": "hi\n\"x\""}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "a": [1], "f": false}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(v.get("f").unwrap().as_bool(), Some(false));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"abc", "[1] x"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn integer_formatting() {
        let v = Json::Num(42.0);
        assert_eq!(v.to_string_compact(), "42");
        let v = Json::Num(1.5);
        assert_eq!(v.to_string_compact(), "1.5");
    }

    #[test]
    fn builder_obj() {
        let v = obj(vec![("k", Json::from(1usize)), ("s", Json::from("v"))]);
        assert_eq!(v.to_string_compact(), r#"{"k":1,"s":"v"}"#);
    }
}
