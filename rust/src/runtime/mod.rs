//! PJRT runtime: load and execute the AOT-compiled XLA artifacts.
//!
//! `make artifacts` runs `python/compile/aot.py` once, lowering the L2 JAX
//! graphs (which embed the L1 Pallas kernels) to **HLO text** under
//! `artifacts/` with a `manifest.json` describing shapes. This module is
//! the only place the `xla` crate is touched: it loads the text, compiles
//! each module once on the PJRT CPU client, caches the executable, and
//! exposes typed f32 entry points. Python never runs at query time.
//!
//! Every artifact entry point has a native-Rust fallback so the crate is
//! fully functional without `artifacts/` (tests assert parity between the
//! two paths).
//!
//! The `xla` crate is behind the off-by-default `pjrt` cargo feature (the
//! default registry does not ship it); without the feature this module
//! still parses manifests and validates shapes, but `run_f32` reports
//! that PJRT execution is not compiled in — callers already handle that
//! error path because it is indistinguishable from "artifacts missing".

use std::collections::HashMap;
use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use crate::jsonio::Json;
use crate::linalg::Mat;

/// Shape+dtype signature of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("spec missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(|d| d.as_str())
            .unwrap_or("f32")
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Manifest entry for one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Artifact registry + compile cache.
pub struct Runtime {
    dir: PathBuf,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    meta: HashMap<String, ArtifactMeta>,
    #[cfg(feature = "pjrt")]
    compiled: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Default artifacts directory (env override: `CHH_ARTIFACTS_DIR`).
    pub fn default_dir() -> PathBuf {
        std::env::var("CHH_ARTIFACTS_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Open the registry. With the `pjrt` feature, fails if PJRT cannot
    /// start; in a default (non-`pjrt`) build it only reads the manifest
    /// and execution fails later, at `run_f32`. A missing manifest is fine
    /// either way (empty registry — native fallbacks everywhere).
    pub fn open(dir: &Path) -> Result<Self> {
        #[cfg(feature = "pjrt")]
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut meta = HashMap::new();
        let manifest = dir.join("manifest.json");
        if manifest.exists() {
            let text = std::fs::read_to_string(&manifest)
                .with_context(|| format!("reading {}", manifest.display()))?;
            let json = Json::parse(&text).context("parsing manifest.json")?;
            let arts = json
                .get("artifacts")
                .and_then(|a| a.as_obj())
                .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
            for (name, entry) in arts {
                let file = dir.join(
                    entry
                        .get("file")
                        .and_then(|f| f.as_str())
                        .ok_or_else(|| anyhow!("artifact {name} missing file"))?,
                );
                let inputs = entry
                    .get("inputs")
                    .and_then(|i| i.as_arr())
                    .ok_or_else(|| anyhow!("artifact {name} missing inputs"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                let outputs = entry
                    .get("outputs")
                    .and_then(|o| o.as_arr())
                    .ok_or_else(|| anyhow!("artifact {name} missing outputs"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                meta.insert(
                    name.clone(),
                    ArtifactMeta { name: name.clone(), file, inputs, outputs },
                );
            }
        }
        Ok(Runtime {
            dir: dir.to_path_buf(),
            #[cfg(feature = "pjrt")]
            client,
            meta,
            #[cfg(feature = "pjrt")]
            compiled: Mutex::new(HashMap::new()),
        })
    }

    /// Open with the default directory.
    pub fn open_default() -> Result<Self> {
        Self::open(&Self::default_dir())
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn has(&self, name: &str) -> bool {
        self.meta.contains_key(name)
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.meta.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.meta.get(name)
    }

    /// Compile (once) and return the cached executable.
    #[cfg(feature = "pjrt")]
    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.compiled.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .meta
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let path = meta
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        // HLO *text* interchange: the xla_extension 0.5.1 proto parser
        // rejects jax≥0.5 64-bit instruction ids; the text parser reassigns
        // them (see /opt/xla-example/README.md).
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("loading {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.compiled.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on f32 buffers. Inputs are validated against the
    /// manifest; outputs are returned as flat f32 vectors in manifest order
    /// (artifacts are lowered with `return_tuple=True`).
    pub fn run_f32(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let meta = self
            .meta
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        if inputs.len() != meta.inputs.len() {
            return Err(anyhow!(
                "artifact {name}: {} inputs given, manifest wants {}",
                inputs.len(),
                meta.inputs.len()
            ));
        }
        for (idx, ((data, shape), spec)) in inputs.iter().zip(meta.inputs.iter()).enumerate() {
            if *shape != spec.shape.as_slice() {
                return Err(anyhow!(
                    "artifact {name} input {idx}: shape {shape:?} != manifest {:?}",
                    spec.shape
                ));
            }
            if data.len() != spec.numel() {
                return Err(anyhow!(
                    "artifact {name} input {idx}: {} elements != {}",
                    data.len(),
                    spec.numel()
                ));
            }
        }
        self.execute_f32(name, &meta, inputs)
    }

    /// Execution half of [`Self::run_f32`] when PJRT is compiled out:
    /// validation has passed, but there is nothing to run the HLO on.
    #[cfg(not(feature = "pjrt"))]
    fn execute_f32(
        &self,
        name: &str,
        _meta: &ArtifactMeta,
        _inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        Err(anyhow!(
            "artifact '{name}' validated, but PJRT execution is not compiled in \
             (build with `--features pjrt`)"
        ))
    }

    /// Execution half of [`Self::run_f32`]: stage literals, run the cached
    /// executable, untuple and validate the outputs.
    #[cfg(feature = "pjrt")]
    fn execute_f32(
        &self,
        name: &str,
        meta: &ArtifactMeta,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let mut lits = Vec::with_capacity(inputs.len());
        for (idx, (data, shape)) in inputs.iter().enumerate() {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape input {idx}: {e:?}"))?;
            lits.push(lit);
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        if parts.len() != meta.outputs.len() {
            return Err(anyhow!(
                "artifact {name}: {} outputs, manifest wants {}",
                parts.len(),
                meta.outputs.len()
            ));
        }
        let mut out = Vec::with_capacity(parts.len());
        for (p, spec) in parts.iter().zip(meta.outputs.iter()) {
            let v = p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            if v.len() != spec.numel() {
                return Err(anyhow!(
                    "artifact {name}: output has {} elements, manifest says {}",
                    v.len(),
                    spec.numel()
                ));
            }
            out.push(v);
        }
        Ok(out)
    }
}

// ───────────────────── batch encoding through artifacts ─────────────────────

/// Tile-batched bilinear encoder backed by the `encode_bh_<profile>`
/// artifact: streams the database through fixed-shape (Tn, d) tiles and
/// packs the sign of the returned pre-sign scores into codes. Produces
/// *identical* codes to [`crate::hash::HashFamily::encode_all`] on the same
/// projections (parity-tested in `rust/tests/`).
pub struct BatchEncoder<'r> {
    rt: &'r Runtime,
    artifact: String,
    tile_n: usize,
    dim: usize,
    k: usize,
}

impl<'r> BatchEncoder<'r> {
    /// Look up the artifact named `encode_bh_<profile>` and read its tile
    /// geometry from the manifest: inputs are X:(Tn,d), U:(d,k), V:(d,k).
    pub fn bilinear(rt: &'r Runtime, profile: &str) -> Result<Self> {
        let name = format!("encode_bh_{profile}");
        let meta = rt
            .meta(&name)
            .ok_or_else(|| anyhow!("artifact {name} missing — run `make artifacts`"))?;
        if meta.inputs.len() != 3 || meta.inputs[0].shape.len() != 2 {
            return Err(anyhow!("artifact {name} has unexpected signature"));
        }
        let tile_n = meta.inputs[0].shape[0];
        let dim = meta.inputs[0].shape[1];
        let k = meta.inputs[1].shape[1];
        Ok(BatchEncoder { rt, artifact: name, tile_n, dim, k })
    }

    pub fn tile_n(&self) -> usize {
        self.tile_n
    }

    pub fn bits(&self) -> usize {
        self.k
    }

    /// Encode all rows of `feats` with projection pairs (u, v) — rows of
    /// `pairs.u`/`pairs.v` are the k projections; the artifact wants them
    /// transposed to (d, k) column-major-by-bit.
    pub fn encode_all(
        &self,
        feats: &crate::data::FeatureStore,
        pairs: &crate::hash::ProjectionPairs,
    ) -> Result<crate::hash::codes::CodeArray> {
        if pairs.dim() != self.dim || pairs.k() != self.k {
            return Err(anyhow!(
                "projection shape ({}, {}) != artifact ({}, {})",
                pairs.k(),
                pairs.dim(),
                self.k,
                self.dim
            ));
        }
        if feats.dim() != self.dim {
            return Err(anyhow!("feature dim {} != artifact dim {}", feats.dim(), self.dim));
        }
        let ut = pairs.u.transpose(); // (d, k)
        let vt = pairs.v.transpose();
        let mut codes = crate::hash::codes::CodeArray::with_capacity(self.k, feats.len());
        let n = feats.len();
        let mut row0 = 0usize;
        while row0 < n {
            let tile: Mat = feats.dense_block(row0, self.tile_n);
            let out = self.rt.run_f32(
                &self.artifact,
                &[
                    (&tile.data, &[self.tile_n, self.dim]),
                    (&ut.data, &[self.dim, self.k]),
                    (&vt.data, &[self.dim, self.k]),
                ],
            )?;
            let scores = &out[0]; // (Tn, k) pre-sign scores
            let valid = (n - row0).min(self.tile_n);
            for r in 0..valid {
                codes.push(crate::hash::codes::pack_signs(&scores[r * self.k..(r + 1) * self.k]));
            }
            row0 += self.tile_n;
        }
        Ok(codes)
    }
}

/// Margin scanner backed by the `margin_scan_<profile>` artifact:
/// |X·w| over fixed tiles — the exhaustive baseline's hot loop on PJRT.
pub struct MarginScanner<'r> {
    rt: &'r Runtime,
    artifact: String,
    tile_n: usize,
    dim: usize,
}

impl<'r> MarginScanner<'r> {
    pub fn open(rt: &'r Runtime, profile: &str) -> Result<Self> {
        let name = format!("margin_scan_{profile}");
        let meta = rt
            .meta(&name)
            .ok_or_else(|| anyhow!("artifact {name} missing — run `make artifacts`"))?;
        let tile_n = meta.inputs[0].shape[0];
        let dim = meta.inputs[0].shape[1];
        Ok(MarginScanner { rt, artifact: name, tile_n, dim })
    }

    /// |wᵀx| for every row (w is NOT normalized here; divide by ‖w‖ for
    /// true margins — ranking is unaffected).
    pub fn scan(&self, feats: &crate::data::FeatureStore, w: &[f32]) -> Result<Vec<f32>> {
        if w.len() != self.dim {
            return Err(anyhow!("w dim {} != artifact dim {}", w.len(), self.dim));
        }
        let n = feats.len();
        let mut out = Vec::with_capacity(n);
        let mut row0 = 0usize;
        while row0 < n {
            let tile = feats.dense_block(row0, self.tile_n);
            let res = self.rt.run_f32(
                &self.artifact,
                &[(&tile.data, &[self.tile_n, self.dim]), (w, &[self.dim])],
            )?;
            let valid = (n - row0).min(self.tile_n);
            out.extend_from_slice(&res[0][..valid]);
            row0 += self.tile_n;
        }
        Ok(out)
    }
}

/// Driver for the `lbh_step_<profile>` artifact: one fused Nesterov step
/// of the §4 per-bit solve executed on PJRT. The trainer pads the sample
/// matrix and residue to the artifact's fixed m (zero rows are
/// gradient-neutral — property-tested in python/tests/test_model.py).
pub struct LbhStepper<'r> {
    rt: &'r Runtime,
    artifact: String,
    /// artifact-fixed training-sample count
    pub m: usize,
    /// feature dimension
    pub dim: usize,
}

impl<'r> LbhStepper<'r> {
    pub fn open(rt: &'r Runtime, profile: &str) -> Result<Self> {
        let name = format!("lbh_step_{profile}");
        let meta = rt
            .meta(&name)
            .ok_or_else(|| anyhow!("artifact {name} missing — run `make artifacts`"))?;
        let m = meta.inputs[0].shape[0];
        let dim = meta.inputs[0].shape[1];
        Ok(LbhStepper { rt, artifact: name, m, dim })
    }

    /// Execute one step. `xm` is (m, d) and `r` is (m, m) — exactly the
    /// artifact shapes (pad before calling). Returns (u_new, v_new, cost).
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        xm: &Mat,
        r: &Mat,
        u: &[f32],
        v: &[f32],
        u_prev: &[f32],
        v_prev: &[f32],
        lr: f32,
        mu: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        if xm.rows != self.m || xm.cols != self.dim {
            return Err(anyhow!(
                "xm is {}x{}, artifact wants {}x{}",
                xm.rows,
                xm.cols,
                self.m,
                self.dim
            ));
        }
        let out = self.rt.run_f32(
            &self.artifact,
            &[
                (&xm.data, &[self.m, self.dim]),
                (&r.data, &[self.m, self.m]),
                (u, &[self.dim]),
                (v, &[self.dim]),
                (u_prev, &[self.dim]),
                (v_prev, &[self.dim]),
                (&[lr], &[1]),
                (&[mu], &[1]),
            ],
        )?;
        let cost = out[2][0];
        let mut it = out.into_iter();
        let u_new = it.next().unwrap();
        let v_new = it.next().unwrap();
        Ok((u_new, v_new, cost))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_from_json() {
        let j = Json::parse(r#"{"shape": [4, 8], "dtype": "f32"}"#).unwrap();
        let s = TensorSpec::from_json(&j).unwrap();
        assert_eq!(s.shape, vec![4, 8]);
        assert_eq!(s.numel(), 32);
        assert_eq!(s.dtype, "f32");
    }

    #[test]
    fn open_missing_manifest_is_empty() {
        let dir = std::env::temp_dir().join(format!("chh_rt_empty_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let rt = Runtime::open(&dir).unwrap();
        assert!(rt.names().is_empty());
        assert!(!rt.has("encode_bh_test"));
        assert!(rt.run_f32("nope", &[]).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_parse_and_validation_errors() {
        let dir = std::env::temp_dir().join(format!("chh_rt_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": {"toy": {"file": "toy.hlo.txt",
                "inputs": [{"shape": [2, 2], "dtype": "f32"}],
                "outputs": [{"shape": [2, 2], "dtype": "f32"}]}}}"#,
        )
        .unwrap();
        let rt = Runtime::open(&dir).unwrap();
        assert!(rt.has("toy"));
        let m = rt.meta("toy").unwrap();
        assert_eq!(m.inputs[0].shape, vec![2, 2]);
        // wrong arity
        assert!(rt.run_f32("toy", &[]).is_err());
        // wrong shape
        let d = [0f32; 4];
        assert!(rt.run_f32("toy", &[(&d, &[4usize] as &[usize])]).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
