//! Retrieval-quality evaluation of hyperplane hash families.
//!
//! The paper reports end-task metrics (MAP, min-margin); this module adds
//! the direct retrieval view a library user needs when picking a family:
//! **recall@T** against the exhaustive ground truth and the **margin
//! ratio** (how much worse the best hashed candidate's margin is than the
//! true minimum). Used by the ablation benches and the `chh eval` command.

use crate::data::FeatureStore;
use crate::hash::HashFamily;
use crate::linalg::{margin_feat, nrm2};
use crate::par::Pool;
use crate::table::HyperplaneIndex;

/// Database rows per parallel work unit in the exhaustive margin scan.
const MARGIN_CHUNK: usize = 4096;

/// Ground truth: indices of the T smallest-margin points for a query
/// (at most `feats.len()` entries).
pub fn exhaustive_topk(feats: &FeatureStore, w: &[f32], t: usize) -> Vec<(usize, f32)> {
    exhaustive_topk_with(feats, w, t, &Pool::serial())
}

/// [`exhaustive_topk`] with the O(n·d) margin scan fanned out over
/// `pool`. Margins are per-row independent and reassembled in row order,
/// so the result is identical for any worker count.
pub fn exhaustive_topk_with(
    feats: &FeatureStore,
    w: &[f32],
    t: usize,
    pool: &Pool,
) -> Vec<(usize, f32)> {
    let wn = nrm2(w);
    let mut all: Vec<(usize, f32)> = pool
        .map(feats.len(), MARGIN_CHUNK, |range| {
            range.map(|i| (i, margin_feat(feats.row(i), w, wn))).collect::<Vec<_>>()
        })
        .into_iter()
        .flatten()
        .collect();
    // partial selection: T smallest margins
    let t = t.min(all.len());
    if t == 0 {
        // empty store (or t = 0): select_nth on an empty slice panics
        return Vec::new();
    }
    all.select_nth_unstable_by(t.saturating_sub(1), |a, b| {
        a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut top: Vec<(usize, f32)> = all[..t].to_vec();
    top.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    top
}

/// One query's retrieval evaluation.
#[derive(Clone, Debug, Default)]
pub struct QueryEval {
    /// |retrieved ∩ truth| / |truth|, where the truth set is the true
    /// top-T — truncated to the database size when `t > n`, so recall can
    /// reach 1.0 on small datasets
    pub recall_at_t: f64,
    /// best retrieved margin / true minimum margin (≥ 1; 1 = perfect —
    /// including when the true minimum is exactly 0 and the probe
    /// retrieved that very point)
    pub margin_ratio: f64,
    /// candidates the hash probe scanned
    pub scanned: usize,
    /// whether the ball was nonempty
    pub nonempty: bool,
}

/// Evaluate one (family, index) on one hyperplane query.
pub fn eval_query(
    family: &dyn HashFamily,
    index: &HyperplaneIndex,
    feats: &FeatureStore,
    w: &[f32],
    t: usize,
) -> QueryEval {
    let truth = exhaustive_topk(feats, w, t);
    let true_best = truth.first().map(|&(_, m)| m).unwrap_or(0.0);
    let truth_set: std::collections::HashSet<usize> = truth.iter().map(|&(i, _)| i).collect();
    let lookup = family.encode_query(w);
    let mut cand = Vec::new();
    index.candidates_into(lookup, usize::MAX, &mut cand);
    let wn = nrm2(w);
    let mut best = f32::INFINITY;
    let mut hits = 0usize;
    for &i in &cand {
        let i = i as usize;
        if truth_set.contains(&i) {
            hits += 1;
        }
        let m = margin_feat(feats.row(i), w, wn);
        if m < best {
            best = m;
        }
    }
    QueryEval {
        // divide by the actual truth-set size, not t: exhaustive_topk
        // truncates to feats.len() when t > n
        recall_at_t: hits as f64 / truth.len().max(1) as f64,
        margin_ratio: if cand.is_empty() {
            f64::INFINITY
        } else if best == true_best {
            // covers true_best == 0 with the on-hyperplane point retrieved
            1.0
        } else if true_best <= 0.0 {
            // genuine miss of a zero-margin point: infinitely worse
            f64::INFINITY
        } else {
            (best / true_best) as f64
        },
        scanned: cand.len(),
        nonempty: !cand.is_empty(),
    }
}

/// Aggregate evaluation over a query set.
#[derive(Clone, Debug, Default)]
pub struct EvalSummary {
    pub queries: usize,
    pub mean_recall: f64,
    pub median_margin_ratio: f64,
    pub mean_scanned: f64,
    pub nonempty_frac: f64,
}

/// Evaluate a family over many hyperplane queries.
pub fn evaluate(
    family: &dyn HashFamily,
    index: &HyperplaneIndex,
    feats: &FeatureStore,
    queries: &[Vec<f32>],
    t: usize,
) -> EvalSummary {
    evaluate_with(family, index, feats, queries, t, &Pool::serial())
}

/// [`evaluate`] with one work unit per query fanned out over `pool` —
/// each query carries its own exhaustive ground-truth scan, the eval
/// bottleneck. Per-query results are aggregated in query order, so the
/// summary is bit-identical for any worker count.
pub fn evaluate_with(
    family: &dyn HashFamily,
    index: &HyperplaneIndex,
    feats: &FeatureStore,
    queries: &[Vec<f32>],
    t: usize,
    pool: &Pool,
) -> EvalSummary {
    let evals: Vec<QueryEval> = pool
        .map(queries.len(), 1, |range| {
            range.map(|q| eval_query(family, index, feats, &queries[q], t)).collect::<Vec<_>>()
        })
        .into_iter()
        .flatten()
        .collect();
    let mut recall = 0.0;
    let mut ratios: Vec<f64> = Vec::new();
    let mut scanned = 0usize;
    let mut nonempty = 0usize;
    for e in &evals {
        recall += e.recall_at_t;
        if e.margin_ratio.is_finite() {
            ratios.push(e.margin_ratio);
        }
        scanned += e.scanned;
        nonempty += e.nonempty as usize;
    }
    ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
    EvalSummary {
        queries: queries.len(),
        mean_recall: recall / queries.len().max(1) as f64,
        median_margin_ratio: ratios.get(ratios.len() / 2).copied().unwrap_or(f64::INFINITY),
        mean_scanned: scanned as f64 / queries.len().max(1) as f64,
        nonempty_frac: nonempty as f64 / queries.len().max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::test_blobs;
    use crate::hash::BhHash;
    use crate::rng::Rng;
    use crate::testing::unit_vec;

    #[test]
    fn exhaustive_topk_sorted_and_correct() {
        let mut rng = Rng::seed_from_u64(1);
        let ds = test_blobs(200, 8, 2, &mut rng);
        let w = unit_vec(&mut rng, 8);
        let top = exhaustive_topk(ds.features(), &w, 10);
        assert_eq!(top.len(), 10);
        for pair in top.windows(2) {
            assert!(pair[0].1 <= pair[1].1);
        }
        // brute force check of the minimum
        let wn = nrm2(&w);
        let bf = (0..200)
            .map(|i| margin_feat(ds.features().row(i), &w, wn))
            .fold(f32::INFINITY, f32::min);
        assert_eq!(top[0].1, bf);
    }

    #[test]
    fn full_ball_index_has_perfect_recall() {
        let mut rng = Rng::seed_from_u64(2);
        let ds = test_blobs(150, 8, 2, &mut rng);
        let fam = BhHash::sample(8, 6, &mut rng);
        // radius = k: every bucket probed → all points are candidates
        let index = HyperplaneIndex::build(&fam, ds.features(), 6);
        let queries: Vec<Vec<f32>> = (0..5).map(|_| unit_vec(&mut rng, 8)).collect();
        let s = evaluate(&fam, &index, ds.features(), &queries, 10);
        assert!((s.mean_recall - 1.0).abs() < 1e-9, "recall {}", s.mean_recall);
        assert!((s.median_margin_ratio - 1.0).abs() < 1e-6);
        assert_eq!(s.mean_scanned, 150.0);
        assert_eq!(s.nonempty_frac, 1.0);
    }

    #[test]
    fn radius_monotonically_improves_recall() {
        let mut rng = Rng::seed_from_u64(3);
        let ds = test_blobs(400, 16, 3, &mut rng);
        let fam = BhHash::sample(16, 12, &mut rng);
        let queries: Vec<Vec<f32>> = (0..10).map(|_| unit_vec(&mut rng, 16)).collect();
        let mut last = -1.0;
        for r in [0usize, 2, 4, 12] {
            let index = HyperplaneIndex::build(&fam, ds.features(), r);
            let s = evaluate(&fam, &index, ds.features(), &queries, 20);
            assert!(
                s.mean_recall >= last - 1e-9,
                "recall must grow with radius: {last} → {} at r={r}",
                s.mean_recall
            );
            last = s.mean_recall;
        }
        assert!((last - 1.0).abs() < 1e-9);
    }

    #[test]
    fn recall_reaches_one_when_t_exceeds_dataset() {
        // regression: with t > n the old denominator (t) capped recall at
        // n/t < 1 even for a perfect retriever
        let mut rng = Rng::seed_from_u64(7);
        let n = 40;
        let ds = test_blobs(n, 8, 2, &mut rng);
        let fam = BhHash::sample(8, 6, &mut rng);
        // full ball: every point retrieved
        let index = HyperplaneIndex::build(&fam, ds.features(), 6);
        let w = unit_vec(&mut rng, 8);
        let e = eval_query(&fam, &index, ds.features(), &w, n * 3);
        assert_eq!(e.scanned, n);
        assert!((e.recall_at_t - 1.0).abs() < 1e-12, "recall {}", e.recall_at_t);
    }

    #[test]
    fn zero_margin_point_retrieved_reports_ratio_one() {
        // one point exactly on the hyperplane (margin 0): retrieving it
        // must report a perfect ratio, not ∞
        let mut m = crate::linalg::Mat::zeros(3, 4);
        m.row_mut(0).copy_from_slice(&[0.0, 2.0, 0.0, 0.0]); // ⟂ w: margin 0
        m.row_mut(1).copy_from_slice(&[1.0, 1.0, 0.0, 0.0]);
        m.row_mut(2).copy_from_slice(&[3.0, 0.0, 1.0, 0.0]);
        let feats = FeatureStore::Dense(m);
        let w = vec![1.0, 0.0, 0.0, 0.0];
        let mut rng = Rng::seed_from_u64(9);
        let fam = BhHash::sample(4, 5, &mut rng);
        let index = HyperplaneIndex::build(&fam, &feats, 5); // full ball
        let e = eval_query(&fam, &index, &feats, &w, 2);
        assert_eq!(e.scanned, 3);
        assert_eq!(e.margin_ratio, 1.0, "exact hit on zero-margin point");
        // an index that misses everything still reports ∞
        let empty = HyperplaneIndex::from_codes(crate::hash::codes::CodeArray::new(5), 0);
        let miss = eval_query(&fam, &empty, &feats, &w, 2);
        assert!(miss.margin_ratio.is_infinite());
    }

    // evaluate_with / exhaustive_topk_with parity across worker counts is
    // covered by the integration suite in rust/tests/batch_parallel.rs.

    #[test]
    fn empty_index_reports_inf_ratio() {
        let mut rng = Rng::seed_from_u64(4);
        let ds = test_blobs(50, 8, 2, &mut rng);
        let fam = BhHash::sample(8, 12, &mut rng);
        // radius 0 with 12 bits: mostly empty for random queries
        let index = HyperplaneIndex::build(&fam, ds.features(), 0);
        let w = unit_vec(&mut rng, 8);
        let e = eval_query(&fam, &index, ds.features(), &w, 5);
        if !e.nonempty {
            assert!(e.margin_ratio.is_infinite());
            assert_eq!(e.scanned, 0);
        }
    }
}
