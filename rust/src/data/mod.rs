//! Datasets and feature stores.
//!
//! The paper evaluates on 20 Newsgroups (18,846 docs, 26,214-d tf-idf,
//! ℓ2-normalized) and Tiny-1M (1.06M GIST-384 images: CIFAR-10 labels plus
//! 1M unlabeled "other" images). Neither is reachable from this offline
//! environment, so this module synthesizes statistical stand-ins
//! (DESIGN.md §2 documents the substitution argument):
//!
//! * [`newsgroups_like`] — Zipf vocabulary, per-class topic distributions,
//!   log-tf·idf weighting, ℓ2 row normalization → sparse CSR.
//! * [`tiny1m_like`] — class prototypes + shared low-rank correlated noise,
//!   plus a "far from every prototype" background class → dense rows.
//!
//! Hyperplane hashing only consumes *angles* between unit-norm vectors, so
//! matching the angle statistics (near-orthogonal sparse text, correlated
//! dense image features) is the property that must be preserved.

use crate::linalg::Mat;
use crate::rng::{Rng, Zipf};
use crate::sparse::{Csr, CsrBuilder, SparseRow};

/// A borrowed feature vector: dense slice or sparse row.
#[derive(Clone, Copy, Debug)]
pub enum FeatRef<'a> {
    Dense(&'a [f32]),
    Sparse(SparseRow<'a>),
}

impl<'a> FeatRef<'a> {
    /// Dot product with a dense vector.
    #[inline]
    pub fn dot(&self, w: &[f32]) -> f32 {
        match self {
            FeatRef::Dense(x) => crate::linalg::dot(x, w),
            FeatRef::Sparse(r) => r.dot_dense(w),
        }
    }

    /// w += alpha * x.
    #[inline]
    pub fn axpy_into(&self, alpha: f32, w: &mut [f32]) {
        match self {
            FeatRef::Dense(x) => crate::linalg::axpy(alpha, x, w),
            FeatRef::Sparse(r) => r.axpy_into(alpha, w),
        }
    }

    #[inline]
    pub fn sq_norm(&self) -> f32 {
        match self {
            FeatRef::Dense(x) => crate::linalg::dot(x, x),
            FeatRef::Sparse(r) => r.sq_norm(),
        }
    }

    /// Random access to coordinate j (O(1) dense, O(log nnz) sparse).
    #[inline]
    pub fn coord(&self, j: usize) -> f32 {
        match self {
            FeatRef::Dense(x) => x[j],
            FeatRef::Sparse(r) => match r.indices.binary_search(&(j as u32)) {
                Ok(p) => r.values[p],
                Err(_) => 0.0,
            },
        }
    }

    /// Scatter into a dense scratch buffer (caller clears between uses).
    pub fn scatter_into(&self, out: &mut [f32]) {
        match self {
            FeatRef::Dense(x) => out[..x.len()].copy_from_slice(x),
            FeatRef::Sparse(r) => r.scatter_into(out),
        }
    }
}

/// Owned feature storage: dense matrix or CSR.
#[derive(Clone, Debug)]
pub enum FeatureStore {
    Dense(Mat),
    Sparse(Csr),
}

impl FeatureStore {
    pub fn len(&self) -> usize {
        match self {
            FeatureStore::Dense(m) => m.rows,
            FeatureStore::Sparse(m) => m.rows,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        match self {
            FeatureStore::Dense(m) => m.cols,
            FeatureStore::Sparse(m) => m.cols,
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> FeatRef<'_> {
        match self {
            FeatureStore::Dense(m) => FeatRef::Dense(m.row(i)),
            FeatureStore::Sparse(m) => FeatRef::Sparse(m.row(i)),
        }
    }

    /// First non-finite value in the store, as `(row, stored_value)` —
    /// `None` when every value is finite. A NaN feature would break
    /// [`crate::hash::codes::pack_signs`]' sgn(0) = +1 convention (NaN
    /// packs as the −1 bit and desynchronizes point vs flipped-query
    /// codes), so ingestion rejects non-finite values up front; see
    /// [`Dataset::new`].
    pub fn find_non_finite(&self) -> Option<(usize, f32)> {
        match self {
            FeatureStore::Dense(m) => {
                for i in 0..m.rows {
                    if let Some(&v) = m.row(i).iter().find(|v| !v.is_finite()) {
                        return Some((i, v));
                    }
                }
                None
            }
            FeatureStore::Sparse(m) => {
                for i in 0..m.rows {
                    let r = m.row(i);
                    if let Some(&v) = r.values.iter().find(|v| !v.is_finite()) {
                        return Some((i, v));
                    }
                }
                None
            }
        }
    }

    /// Densify rows [row0, row0+n) zero-padded — PJRT tile staging.
    pub fn dense_block(&self, row0: usize, n: usize) -> Mat {
        match self {
            FeatureStore::Sparse(m) => m.dense_block(row0, n),
            FeatureStore::Dense(m) => {
                let mut out = Mat::zeros(n, m.cols);
                for r in 0..n {
                    let i = row0 + r;
                    if i >= m.rows {
                        break;
                    }
                    out.row_mut(r).copy_from_slice(m.row(i));
                }
                out
            }
        }
    }
}

/// A labeled dataset for one-vs-all active learning.
#[derive(Clone, Debug)]
pub struct Dataset {
    features: FeatureStore,
    labels: Vec<u16>,
    /// classes eligible for one-vs-all AL evaluation (the Tiny profile has
    /// an extra "other" label == eval_classes that is never a positive).
    eval_classes: usize,
    pub name: String,
}

impl Dataset {
    /// Build a dataset. Panics if a feature value is non-finite: the HTTP
    /// server already 400s non-finite query hyperplanes, and this is the
    /// matching gate for stored features — a NaN reaching
    /// [`crate::hash::codes::pack_signs`] would silently pack as the −1
    /// bit (breaking sgn(0) = +1) rather than fail loudly here.
    pub fn new(features: FeatureStore, labels: Vec<u16>, eval_classes: usize, name: &str) -> Self {
        assert_eq!(features.len(), labels.len());
        if let Some((row, v)) = features.find_non_finite() {
            panic!("dataset {name}: non-finite feature {v} in row {row}");
        }
        Dataset { features, labels, eval_classes, name: name.to_string() }
    }

    pub fn len(&self) -> usize {
        self.features.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        self.features.dim()
    }

    pub fn features(&self) -> &FeatureStore {
        &self.features
    }

    pub fn labels(&self) -> &[u16] {
        &self.labels
    }

    pub fn eval_classes(&self) -> usize {
        self.eval_classes
    }

    /// Binary one-vs-all relevance for class c.
    pub fn binary_labels(&self, c: u16) -> Vec<bool> {
        self.labels.iter().map(|&l| l == c).collect()
    }

    /// Indices of points with label c.
    pub fn class_indices(&self, c: u16) -> Vec<usize> {
        (0..self.len()).filter(|&i| self.labels[i] == c).collect()
    }
}

// ───────────────────────── newsgroups-like corpus ─────────────────────────

/// Configuration of the synthetic 20-Newsgroups-like corpus.
#[derive(Clone, Debug)]
pub struct NewsConfig {
    /// number of documents (paper: 18,846)
    pub n: usize,
    /// vocabulary size = feature dimension (paper: 26,214; default reduced
    /// to keep AOT artifact shapes manageable — documented in DESIGN.md §2)
    pub vocab: usize,
    /// number of classes (paper: 20)
    pub classes: usize,
    /// topic words per class
    pub topic_words: usize,
    /// probability a token is drawn from the class topic vs global Zipf
    pub topic_mix: f64,
    /// lognormal document length parameters
    pub len_mu: f64,
    pub len_sigma: f64,
    /// Zipf exponent of the global vocabulary distribution
    pub zipf_s: f64,
}

impl Default for NewsConfig {
    fn default() -> Self {
        NewsConfig {
            n: 18_846,
            vocab: 1024,
            classes: 20,
            topic_words: 40,
            topic_mix: 0.18,
            len_mu: 3.8,   // median ~74 tokens
            len_sigma: 0.6,
            zipf_s: 1.05,
        }
    }
}

/// Generate a sparse tf-idf corpus with class-dependent topics.
///
/// Mirrors 20 Newsgroups' structure: classes come in confusable sibling
/// pairs (comp.sys.ibm vs comp.sys.mac, rec.sport.baseball vs hockey, …),
/// modeled by letting class c share half its topic vocabulary with class
/// c^1. This keeps the one-vs-all problems from saturating at AP = 1 the
/// way fully disjoint topics would.
pub fn newsgroups_like(cfg: &NewsConfig, rng: &mut Rng) -> Dataset {
    assert!(cfg.classes >= 2 && cfg.vocab > cfg.topic_words * 2);
    let zipf = Zipf::new(cfg.vocab, cfg.zipf_s);
    // Topic sets: half shared with the sibling class (c ^ 1), half own;
    // drawn away from the most frequent (stopword-like) ranks.
    let group_sets: Vec<Vec<u32>> = (0..cfg.classes.div_ceil(2))
        .map(|_| {
            (0..cfg.topic_words / 2)
                .map(|_| rng.range(cfg.vocab / 20, cfg.vocab) as u32)
                .collect()
        })
        .collect();
    let topic_sets: Vec<Vec<u32>> = (0..cfg.classes)
        .map(|c| {
            let mut set: Vec<u32> = group_sets[c / 2].clone();
            set.extend(
                (0..cfg.topic_words - set.len())
                    .map(|_| rng.range(cfg.vocab / 20, cfg.vocab) as u32),
            );
            set
        })
        .collect();

    let mut builder = CsrBuilder::new(cfg.vocab);
    let mut labels = Vec::with_capacity(cfg.n);
    let mut entries: Vec<(u32, f32)> = Vec::new();
    let mut counts: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
    for i in 0..cfg.n {
        let c = (i % cfg.classes) as u16; // balanced classes
        labels.push(c);
        let len = rng.lognormal(cfg.len_mu, cfg.len_sigma).round().max(5.0) as usize;
        counts.clear();
        for _ in 0..len {
            let word = if rng.bernoulli(cfg.topic_mix) {
                *rng.choose(&topic_sets[c as usize])
            } else {
                zipf.sample(rng) as u32
            };
            *counts.entry(word).or_insert(0) += 1;
        }
        entries.clear();
        for (&w, &tf) in counts.iter() {
            // sublinear tf weighting, standard for text
            entries.push((w, 1.0 + (tf as f32).ln()));
        }
        builder.push_row(&mut entries);
    }
    let mut m = builder.finish();
    // idf
    let df = m.column_doc_freq();
    let idf: Vec<f32> = df
        .iter()
        .map(|&d| ((cfg.n as f32 + 1.0) / (d as f32 + 1.0)).ln().max(0.0))
        .collect();
    m.scale_columns(&idf);
    m.l2_normalize_rows();
    // shuffle row order so class blocks don't align with tile boundaries
    let mut perm: Vec<usize> = (0..cfg.n).collect();
    rng.shuffle(&mut perm);
    let mut b2 = CsrBuilder::new(cfg.vocab);
    let mut labels2 = Vec::with_capacity(cfg.n);
    let mut tmp: Vec<(u32, f32)> = Vec::new();
    for &i in &perm {
        let r = m.row(i);
        tmp.clear();
        tmp.extend(r.indices.iter().copied().zip(r.values.iter().copied()));
        b2.push_row(&mut tmp);
        labels2.push(labels[i]);
    }
    Dataset::new(FeatureStore::Sparse(b2.finish()), labels2, cfg.classes, "newsgroups-like")
}

// ───────────────────────── tiny1m-like images ─────────────────────────

/// Configuration of the synthetic Tiny-1M-like GIST corpus.
#[derive(Clone, Debug)]
pub struct TinyConfig {
    /// total points (paper: 1.06M; default scaled for a 1-core testbed)
    pub n: usize,
    /// GIST dimensionality (paper: 384)
    pub d: usize,
    /// labeled object classes (paper/CIFAR-10: 10)
    pub classes: usize,
    /// fraction of points in the labeled core (CIFAR: 60k/1.06M ≈ 0.0566)
    pub core_frac: f64,
    /// low-rank correlated-noise dimensionality
    pub noise_rank: usize,
    /// prototype separation scale
    pub proto_scale: f32,
    /// correlated / isotropic noise scales
    pub corr_noise: f32,
    pub iso_noise: f32,
}

impl Default for TinyConfig {
    fn default() -> Self {
        TinyConfig {
            n: 100_000,
            d: 384,
            classes: 10,
            core_frac: 0.0566,
            noise_rank: 32,
            proto_scale: 1.0,
            corr_noise: 0.85,
            iso_noise: 0.55,
        }
    }
}

/// Generate a dense GIST-like corpus: `classes` labeled prototypes plus an
/// "other" background class (label == classes) sampled far from the
/// prototypes — mirroring how Tiny-1M's extra million images were chosen as
/// the farthest from the CIFAR-10 mean.
pub fn tiny1m_like(cfg: &TinyConfig, rng: &mut Rng) -> Dataset {
    assert!(cfg.classes >= 2 && cfg.d >= 8);
    // Prototypes come in confusable sibling pairs (CIFAR's cat/dog,
    // automobile/truck, ...): class c shares a group direction with c^1.
    let group_dirs: Vec<Vec<f32>> = (0..cfg.classes.div_ceil(2))
        .map(|_| {
            let mut g = rng.gauss_vec(cfg.d);
            crate::linalg::normalize(&mut g);
            g
        })
        .collect();
    // Each class is MULTI-MODAL (4 sub-prototypes around a class core):
    // real GIST categories are; it keeps the linear SVM improvable long
    // past the initial labels, which is what makes AL curves rise.
    const MODES: usize = 4;
    let protos: Vec<Vec<Vec<f32>>> = (0..cfg.classes)
        .map(|c| {
            let mut own = rng.gauss_vec(cfg.d);
            crate::linalg::normalize(&mut own);
            let mut core = group_dirs[c / 2].clone();
            crate::linalg::axpy(0.8, &own, &mut core);
            crate::linalg::normalize(&mut core);
            (0..MODES)
                .map(|_| {
                    let mut mode_dir = rng.gauss_vec(cfg.d);
                    crate::linalg::normalize(&mut mode_dir);
                    let mut p = core.clone();
                    crate::linalg::axpy(0.8, &mode_dir, &mut p);
                    crate::linalg::normalize(&mut p);
                    crate::linalg::scal(cfg.proto_scale, &mut p);
                    p
                })
                .collect()
        })
        .collect();
    // shared low-rank basis for correlated noise
    let basis: Vec<Vec<f32>> = (0..cfg.noise_rank)
        .map(|_| {
            let mut b = rng.gauss_vec(cfg.d);
            crate::linalg::normalize(&mut b);
            b
        })
        .collect();
    let n_core = ((cfg.n as f64) * cfg.core_frac).round() as usize;
    let n_core = n_core.clamp(cfg.classes * 10, cfg.n);
    let mut data = Mat::zeros(cfg.n, cfg.d);
    let mut labels = vec![0u16; cfg.n];
    // interleave core and background so tiles mix both
    for i in 0..cfg.n {
        let is_core = (i as u64 * n_core as u64 / cfg.n as u64)
            != ((i as u64 + 1) * n_core as u64 / cfg.n as u64);
        let row = data.row_mut(i);
        // correlated noise: sum of noise_rank basis directions
        for b in &basis {
            let z = rng.gauss_f32() * cfg.corr_noise / (cfg.noise_rank as f32).sqrt();
            crate::linalg::axpy(z, b, row);
        }
        for v in row.iter_mut() {
            *v += rng.gauss_f32() * cfg.iso_noise / (cfg.d as f32).sqrt();
        }
        if is_core {
            let c = rng.below(cfg.classes) as u16;
            labels[i] = c;
            // variable prototype strength: weakly-prototypical members are
            // the hard positives an active learner finds near the boundary
            // (real GIST classes have exactly this radial spread)
            let strength = 0.4 + 0.9 * rng.f32();
            let mode = rng.below(MODES);
            crate::linalg::axpy(strength, &protos[c as usize][mode], row);
        } else {
            // background ("other" class): each point gets its OWN random
            // direction — in high dimension these are near-orthogonal to
            // every prototype (matching how Tiny-1M's extra million images
            // were picked as farthest from the CIFAR mean) and, crucially,
            // *diverse*: near-boundary negatives pull the SVM in canceling
            // directions instead of a coherent anti-prototype drift.
            labels[i] = cfg.classes as u16;
            let mut dir = rng.gauss_vec(cfg.d);
            crate::linalg::normalize(&mut dir);
            crate::linalg::axpy(cfg.proto_scale * 0.9, &dir, row);
            // a fraction of the background sits near a prototype: hard
            // distractors (GIST lookalikes that are not the object class)
            if rng.bernoulli(0.25) {
                let c = rng.below(cfg.classes);
                crate::linalg::axpy(0.7, &protos[c][rng.below(MODES)], row);
            }
        }
    }
    data.l2_normalize_rows();
    Dataset::new(FeatureStore::Dense(data), labels, cfg.classes, "tiny1m-like")
}

/// Small dense dataset for tests: well-separated Gaussian blobs.
pub fn test_blobs(n: usize, d: usize, classes: usize, rng: &mut Rng) -> Dataset {
    let protos: Vec<Vec<f32>> = (0..classes)
        .map(|_| {
            let mut p = rng.gauss_vec(d);
            crate::linalg::normalize(&mut p);
            crate::linalg::scal(2.0, &mut p);
            p
        })
        .collect();
    let mut data = Mat::zeros(n, d);
    let mut labels = vec![0u16; n];
    for i in 0..n {
        let c = i % classes;
        labels[i] = c as u16;
        let row = data.row_mut(i);
        row.copy_from_slice(&protos[c]);
        for v in row.iter_mut() {
            *v += rng.gauss_f32() * 0.4;
        }
    }
    data.l2_normalize_rows();
    Dataset::new(FeatureStore::Dense(data), labels, classes, "test-blobs")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cosine;

    #[test]
    fn news_shapes_and_normalization() {
        let cfg = NewsConfig { n: 200, vocab: 512, classes: 4, ..NewsConfig::default() };
        let mut rng = Rng::seed_from_u64(1);
        let ds = newsgroups_like(&cfg, &mut rng);
        assert_eq!(ds.len(), 200);
        assert_eq!(ds.dim(), 512);
        assert_eq!(ds.eval_classes(), 4);
        for i in 0..ds.len() {
            let n = ds.features().row(i).sq_norm().sqrt();
            assert!((n - 1.0).abs() < 1e-4, "row {i} norm {n}");
        }
    }

    #[test]
    fn news_classes_balanced() {
        let cfg = NewsConfig { n: 400, vocab: 512, classes: 4, ..NewsConfig::default() };
        let mut rng = Rng::seed_from_u64(2);
        let ds = newsgroups_like(&cfg, &mut rng);
        for c in 0..4 {
            let cnt = ds.class_indices(c).len();
            assert_eq!(cnt, 100, "class {c}");
        }
    }

    #[test]
    fn news_same_class_more_similar() {
        // topic structure ⇒ average within-class cosine > between-class
        let cfg = NewsConfig { n: 300, vocab: 512, classes: 3, ..NewsConfig::default() };
        let mut rng = Rng::seed_from_u64(3);
        let ds = newsgroups_like(&cfg, &mut rng);
        let dense = match ds.features() {
            FeatureStore::Sparse(m) => m.to_dense(),
            _ => unreachable!(),
        };
        let (mut within, mut wn, mut between, mut bn) = (0.0f64, 0, 0.0f64, 0);
        for i in 0..100 {
            for j in (i + 1)..100 {
                let c = cosine(dense.row(i), dense.row(j)) as f64;
                if ds.labels()[i] == ds.labels()[j] {
                    within += c;
                    wn += 1;
                } else {
                    between += c;
                    bn += 1;
                }
            }
        }
        assert!(within / wn as f64 > between / bn as f64 + 0.01);
    }

    #[test]
    fn tiny_shapes_and_other_class() {
        let cfg = TinyConfig { n: 2000, d: 64, ..TinyConfig::default() };
        let mut rng = Rng::seed_from_u64(4);
        let ds = tiny1m_like(&cfg, &mut rng);
        assert_eq!(ds.len(), 2000);
        assert_eq!(ds.dim(), 64);
        assert_eq!(ds.eval_classes(), 10);
        let n_other = ds.class_indices(10).len();
        // background dominates (core_frac ≈ 5.7%)
        assert!(n_other > 1700, "other = {n_other}");
        let n_core: usize = (0..10).map(|c| ds.class_indices(c).len()).sum();
        assert_eq!(n_core + n_other, 2000);
        assert!(n_core > 50);
    }

    #[test]
    fn tiny_rows_unit_norm() {
        let cfg = TinyConfig { n: 100, d: 32, ..TinyConfig::default() };
        let mut rng = Rng::seed_from_u64(5);
        let ds = tiny1m_like(&cfg, &mut rng);
        for i in 0..ds.len() {
            let n = ds.features().row(i).sq_norm().sqrt();
            assert!((n - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn tiny_core_clusters_tighter_than_background() {
        let cfg = TinyConfig { n: 3000, d: 64, ..TinyConfig::default() };
        let mut rng = Rng::seed_from_u64(6);
        let ds = tiny1m_like(&cfg, &mut rng);
        let m = match ds.features() {
            FeatureStore::Dense(m) => m,
            _ => unreachable!(),
        };
        // same-class core pairs should have higher cosine than core-background
        let c0 = ds.class_indices(0);
        let other = ds.class_indices(10);
        assert!(c0.len() >= 2 && other.len() >= 2);
        let mut same = 0.0f64;
        let mut cnt = 0usize;
        for i in 0..c0.len().min(20) {
            for j in (i + 1)..c0.len().min(20) {
                same += cosine(m.row(c0[i]), m.row(c0[j])) as f64;
                cnt += 1;
            }
        }
        same /= cnt as f64;
        let mut cross = 0.0f64;
        let mut ccnt = 0usize;
        for i in 0..c0.len().min(20) {
            for j in 0..other.len().min(20) {
                cross += cosine(m.row(c0[i]), m.row(other[j])) as f64;
                ccnt += 1;
            }
        }
        cross /= ccnt as f64;
        assert!(same > cross + 0.05, "same {same} cross {cross}");
    }

    #[test]
    fn featref_coord_and_scatter() {
        let mut b = CsrBuilder::new(6);
        b.push_row(&mut vec![(1, 2.0), (4, -1.0)]);
        let m = b.finish();
        let r = FeatRef::Sparse(m.row(0));
        assert_eq!(r.coord(1), 2.0);
        assert_eq!(r.coord(0), 0.0);
        assert_eq!(r.coord(4), -1.0);
        let mut buf = vec![0.0f32; 6];
        r.scatter_into(&mut buf);
        assert_eq!(buf, vec![0.0, 2.0, 0.0, 0.0, -1.0, 0.0]);
    }

    #[test]
    fn non_finite_features_rejected_at_ingest() {
        let mut m = Mat::zeros(3, 4);
        m.set(2, 1, f32::NAN);
        let store = FeatureStore::Dense(m);
        assert_eq!(store.find_non_finite().map(|(r, _)| r), Some(2));
        let ok = FeatureStore::Dense(Mat::zeros(2, 4));
        assert!(ok.find_non_finite().is_none());
        let mut b = CsrBuilder::new(4);
        b.push_row(&mut vec![(0, 1.0)]);
        b.push_row(&mut vec![(2, f32::INFINITY)]);
        let sparse = FeatureStore::Sparse(b.finish());
        assert_eq!(sparse.find_non_finite(), Some((1, f32::INFINITY)));
    }

    #[test]
    #[should_panic(expected = "non-finite feature")]
    fn dataset_new_panics_on_nan_feature() {
        let mut m = Mat::zeros(2, 3);
        m.set(0, 0, f32::NAN);
        Dataset::new(FeatureStore::Dense(m), vec![0, 1], 2, "bad");
    }

    #[test]
    fn dense_block_round_trip() {
        let mut rng = Rng::seed_from_u64(7);
        let ds = test_blobs(10, 8, 2, &mut rng);
        let blk = ds.features().dense_block(8, 4);
        assert_eq!(blk.rows, 4);
        // rows 8,9 copied; rows 10,11 zero padded
        match ds.features() {
            FeatureStore::Dense(m) => {
                assert_eq!(blk.row(0), m.row(8));
                assert_eq!(blk.row(1), m.row(9));
            }
            _ => unreachable!(),
        }
        assert!(blk.row(2).iter().all(|&v| v == 0.0));
    }
}
