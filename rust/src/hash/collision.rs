//! Collision-probability theory (§3 of the paper) and its Monte-Carlo
//! validation — the machinery behind Fig. 2(a)/(b).
//!
//! All probabilities are parameterized by the paper's distance measure
//! `r = D(x, P_w) = α²_{x,w} ∈ [0, π²/4]`.

use crate::data::FeatRef;
use crate::hash::{AhHash, BhHash, EhHash, HashFamily};
use crate::rng::Rng;
use crate::testing::pair_with_angle;
use std::f64::consts::PI;

/// Domain upper bound for r: (π/2)².
pub const R_MAX: f64 = PI * PI / 4.0;

/// AH-Hash collision probability (eq. 3): p₁ = 1/4 − r/π².
pub fn p_ah(r: f64) -> f64 {
    0.25 - r / (PI * PI)
}

/// EH-Hash collision probability (eq. 5): p₁ = acos(sin²α)/π, α = √r.
pub fn p_eh(r: f64) -> f64 {
    let alpha = r.sqrt();
    (alpha.sin().powi(2)).acos() / PI
}

/// BH-Hash collision probability (Lemma 1): p₁ = 1/2 − 2r/π².
pub fn p_bh(r: f64) -> f64 {
    0.5 - 2.0 * r / (PI * PI)
}

/// Query-time exponent ρ = ln p₁(r) / ln p₂(r(1+ε)) (Theorem 2).
/// Returns NaN where p₂ ≤ 0 (the regime where the family's guarantee
/// lapses), matching how the paper's Fig. 2(b) curves terminate.
pub fn rho(p: impl Fn(f64) -> f64, r: f64, eps: f64) -> f64 {
    let p1 = p(r);
    let p2 = p(r * (1.0 + eps));
    if p1 <= 0.0 || p2 <= 0.0 || p1 >= 1.0 || p2 >= 1.0 {
        return f64::NAN;
    }
    p1.ln() / p2.ln()
}

/// Theorem 2's table count `n^ρ` and per-table bits `k = log_{1/p₂} n`.
pub fn theorem2_params(p: impl Fn(f64) -> f64, r: f64, eps: f64, n: usize) -> Option<(usize, usize)> {
    let p2 = p(r * (1.0 + eps));
    if p2 <= 0.0 || p2 >= 1.0 {
        return None;
    }
    let rho = rho(&p, r, eps);
    if !rho.is_finite() {
        return None;
    }
    let tables = (n as f64).powf(rho).ceil() as usize;
    let bits = ((n as f64).ln() / (1.0 / p2).ln()).ceil() as usize;
    Some((tables.max(1), bits.max(1)))
}

/// Monte-Carlo estimate of the single-bit collision probability
/// `Pr[h(P_w) = h(x)]` at point-to-hyperplane angle α, for a family
/// constructed fresh per trial (so randomness is over (u, v) draws).
///
/// `make` builds a 1-bit-per-function family; collisions are counted on
/// bit 0 of `encode_query` vs `encode_point`.
pub fn mc_collision<F, H>(
    alpha: f64,
    dim: usize,
    trials: usize,
    rng: &mut Rng,
    mut make: F,
) -> f64
where
    F: FnMut(&mut Rng) -> H,
    H: HashFamily,
{
    // point-to-hyperplane angle α ⇒ angle from the normal θ = π/2 − α
    let theta = (PI / 2.0 - alpha) as f32;
    let mut hits = 0usize;
    for _ in 0..trials {
        let fam = make(rng);
        let (w, x) = pair_with_angle(rng, dim, theta);
        let q = fam.encode_query(&w);
        let p = fam.encode_point(FeatRef::Dense(&x));
        if (q ^ p) & 1 == 0 {
            hits += 1;
        }
    }
    hits as f64 / trials as f64
}

// ──────────────────── probe-ordering model (online serving) ────────────────────

/// Per-bit collision model backing the online [`crate::online::ProbePlanner`]:
/// a *target* (near-hyperplane) point matches each lookup bit independently
/// with probability `p₁(r_target)`, while a *background* point matches with
/// `p₁(r_background) < p₁(r_target)` (Lemma 1 is monotone decreasing in r).
/// A bucket at flip-mask `m` is worth probing in proportion to how strongly
/// it is *enriched* in targets relative to background — the likelihood ratio
///
/// ```text
/// L(m) = Π_{j∈m} (1−p_t)/(1−p_b) · Π_{j∉m} p_t/p_b
/// ```
///
/// which decays by a constant odds factor per flipped bit. The planner works
/// in −log space: each flipped bit costs [`CollisionModel::bit_cost`] ≥ 0 and
/// best-first probing visits masks by ascending total cost (descending
/// modeled collision mass).
#[derive(Clone, Copy, Debug)]
pub struct CollisionModel {
    /// distance r = α² the retrieval targets sit at (small)
    pub r_target: f64,
    /// distance of the background bulk (large)
    pub r_background: f64,
}

impl CollisionModel {
    /// Defaults matched to the paper's regime: targets within α ≈ 0.15 rad
    /// of the hyperplane against a bulk at the domain midpoint.
    pub fn bh_default() -> Self {
        CollisionModel { r_target: 0.15 * 0.15, r_background: 0.5 * R_MAX }
    }

    /// The per-flipped-bit log-odds cost
    /// `ln[(p_t/(1−p_t)) / (p_b/(1−p_b))]` under the BH family (Lemma 1),
    /// clamped to be non-negative and finite.
    pub fn bit_cost(&self) -> f64 {
        let clamp = |p: f64| p.clamp(1e-6, 0.5);
        let pt = clamp(p_bh(self.r_target));
        let pb = clamp(p_bh(self.r_background));
        let odds = |p: f64| p / (1.0 - p);
        (odds(pt) / odds(pb)).ln().max(0.0)
    }
}

/// Modeled (relative) collision mass of probing flip-mask `mask` when bit j
/// costs `costs[j]`: `exp(−Σ_{j∈mask} costs[j])`, normalized so the exact
/// bucket (empty mask) has mass 1.
pub fn probe_mass(mask: u64, costs: &[f64]) -> f64 {
    let mut total = 0.0f64;
    for (j, &c) in costs.iter().enumerate() {
        if (mask >> j) & 1 == 1 {
            total += c;
        }
    }
    (-total).exp()
}

/// Convenience Monte-Carlo estimators for the three randomized families.
pub fn mc_bh(alpha: f64, dim: usize, trials: usize, rng: &mut Rng) -> f64 {
    mc_collision(alpha, dim, trials, rng, |r| BhHash::sample(dim, 1, r))
}

pub fn mc_eh(alpha: f64, dim: usize, trials: usize, rng: &mut Rng) -> f64 {
    mc_collision(alpha, dim, trials, rng, |r| EhHash::full(dim, 1, r))
}

/// AH is dual-bit: collision = both bits equal (eq. 3 measures the 2-bit
/// bucket collision), so compare the full 2-bit code.
pub fn mc_ah(alpha: f64, dim: usize, trials: usize, rng: &mut Rng) -> f64 {
    let theta = (PI / 2.0 - alpha) as f32;
    let mut hits = 0usize;
    for _ in 0..trials {
        let fam = AhHash::sample(dim, 1, rng);
        let (w, x) = pair_with_angle(rng, dim, theta);
        if fam.encode_query(&w) == fam.encode_point(FeatRef::Dense(&x)) {
            hits += 1;
        }
    }
    hits as f64 / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn analytic_endpoints() {
        // r = 0 (perpendicular, most informative)
        assert!(close(p_ah(0.0), 0.25, 1e-12));
        assert!(close(p_bh(0.0), 0.5, 1e-12));
        assert!(close(p_eh(0.0), 0.5, 1e-12));
        // r = (π/2)² (parallel, most uninformative)
        assert!(close(p_ah(R_MAX), 0.0, 1e-12));
        assert!(close(p_bh(R_MAX), 0.0, 1e-12));
        assert!(close(p_eh(R_MAX), 0.0, 1e-9));
    }

    #[test]
    fn bh_doubles_ah() {
        // Lemma 1 remark: BH collision probability is exactly 2× AH's.
        for i in 0..20 {
            let r = R_MAX * i as f64 / 20.0;
            assert!(close(p_bh(r), 2.0 * p_ah(r), 1e-12), "r={r}");
        }
    }

    #[test]
    fn probabilities_monotone_decreasing() {
        let mut last = (p_ah(0.0), p_eh(0.0), p_bh(0.0));
        for i in 1..=50 {
            let r = R_MAX * i as f64 / 50.0;
            let cur = (p_ah(r), p_eh(r), p_bh(r));
            assert!(cur.0 < last.0 && cur.1 < last.1 && cur.2 < last.2, "r={r}");
            last = cur;
        }
    }

    #[test]
    fn bh_highest_collision_probability() {
        // Fig 2(a): at any fixed r, BH-Hash has the highest p₁.
        for i in 0..=20 {
            let r = R_MAX * i as f64 / 21.0;
            assert!(p_bh(r) >= p_eh(r) - 1e-12, "r={r}: bh {} eh {}", p_bh(r), p_eh(r));
            assert!(p_bh(r) > p_ah(r), "r={r}");
        }
    }

    #[test]
    fn rho_in_unit_interval_and_eh_smallest() {
        // Fig 2(b): 0 < ρ < 1; EH has slightly smaller ρ than BH.
        let eps = 3.0;
        for i in 1..=10 {
            let r = 0.2 * i as f64 * R_MAX / 10.0; // keep r(1+ε) in-domain
            if p_ah(r * (1.0 + eps)) <= 0.0 {
                continue;
            }
            for p in [p_ah as fn(f64) -> f64, p_eh, p_bh] {
                let rr = rho(p, r, eps);
                assert!(rr > 0.0 && rr < 1.0, "rho {rr} at r={r}");
            }
            assert!(
                rho(p_eh, r, eps) <= rho(p_bh, r, eps) + 1e-9,
                "EH rho should be ≤ BH rho at r={r}"
            );
        }
    }

    #[test]
    fn theorem2_params_reasonable() {
        let (tables, bits) = theorem2_params(p_bh, 0.1, 3.0, 100_000).unwrap();
        assert!(tables >= 1);
        assert!(bits >= 10, "bits {bits}");
        // out-of-domain r(1+ε) → None
        assert!(theorem2_params(p_ah, R_MAX, 3.0, 100).is_none());
    }

    #[test]
    fn collision_model_cost_positive_and_monotone() {
        let m = CollisionModel::bh_default();
        let c = m.bit_cost();
        assert!(c > 0.0 && c.is_finite(), "cost {c}");
        // widening the target/background gap raises the per-bit cost
        let tighter = CollisionModel { r_target: 0.01, r_background: 0.9 * R_MAX };
        assert!(tighter.bit_cost() > c);
        // degenerate model (target == background) has zero cost: all probes
        // equally worthwhile, planner falls back to weight ordering
        let flat = CollisionModel { r_target: 0.3, r_background: 0.3 };
        assert!(flat.bit_cost().abs() < 1e-12);
    }

    #[test]
    fn probe_mass_multiplies_per_flipped_bit() {
        let costs = vec![0.5f64, 1.0, 2.0];
        assert!((probe_mass(0b000, &costs) - 1.0).abs() < 1e-12);
        assert!((probe_mass(0b001, &costs) - (-0.5f64).exp()).abs() < 1e-12);
        assert!((probe_mass(0b110, &costs) - (-3.0f64).exp()).abs() < 1e-12);
        // more flips at equal cost ⇒ strictly less mass
        assert!(probe_mass(0b111, &costs) < probe_mass(0b011, &costs));
    }

    #[test]
    fn mc_matches_lemma1_bh() {
        // Monte-Carlo single-bit collision at a few α values vs Lemma 1.
        let mut rng = Rng::seed_from_u64(42);
        for &alpha in &[0.0f64, 0.4, 0.9, 1.4] {
            let est = mc_bh(alpha, 24, 4000, &mut rng);
            let want = p_bh(alpha * alpha);
            assert!(
                close(est, want, 0.035),
                "alpha={alpha}: mc {est} vs analytic {want}"
            );
        }
    }

    #[test]
    fn mc_matches_eq3_ah() {
        let mut rng = Rng::seed_from_u64(43);
        for &alpha in &[0.0f64, 0.7, 1.3] {
            let est = mc_ah(alpha, 24, 4000, &mut rng);
            let want = p_ah(alpha * alpha);
            assert!(close(est, want, 0.035), "alpha={alpha}: mc {est} vs {want}");
        }
    }

    #[test]
    fn mc_matches_eq5_eh() {
        let mut rng = Rng::seed_from_u64(44);
        for &alpha in &[0.0f64, 0.8, 1.4] {
            let est = mc_eh(alpha, 12, 2500, &mut rng);
            let want = p_eh(alpha * alpha);
            assert!(close(est, want, 0.04), "alpha={alpha}: mc {est} vs {want}");
        }
    }
}
