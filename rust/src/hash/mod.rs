//! Hyperplane hash-function families.
//!
//! Implements the paper's bilinear families and the two randomized
//! baselines of Jain et al. (NIPS 2010):
//!
//! * [`AhHash`] — Angle-Hyperplane Hash (eq. 2): the dual-bit linear
//!   function `[sgn(uᵀz), sgn(vᵀz)]`; a hyperplane query flips the sign of
//!   the second projection.
//! * [`EhHash`] — Embedding-Hyperplane Hash (eq. 4): `sgn(Uᵀvec(zzᵀ))` on
//!   the d²-dimensional rank-one embedding; hyperplane queries negate the
//!   embedding. Includes the dimension-sampling acceleration used in the
//!   paper's experiments.
//! * [`BhHash`] — the paper's Bilinear-Hyperplane Hash (eq. 6–7):
//!   `sgn(uᵀz · zᵀv)`, i.e. the XNOR of AH's two bits, with twice AH's
//!   collision probability (Lemma 1).
//! * [`LbhHash`] — learned bilinear functions (§4): identical query-time
//!   form as BH but with projection pairs trained by [`crate::lbh`].
//!
//! The common query protocol lives in [`HashFamily`]: a database point is
//! encoded with `encode_point`; a hyperplane with normal `w` is looked up
//! at `encode_query(w)`, already transformed per family so that
//! *informative points collide with the lookup code*.

pub mod codes;
pub mod collision;
pub mod fasthash;

use crate::data::FeatRef;
use crate::linalg::Mat;
use crate::par::Pool;
use crate::rng::Rng;
use codes::{flip, pack_signs};

/// Rows per parallel work unit in the batch-encode paths. Fixed (never
/// derived from the worker count) so chunk boundaries — and with them any
/// accumulation order — are identical for every `workers` setting; see
/// the determinism contract in [`crate::par`].
pub const ENCODE_CHUNK: usize = 1024;

/// A family of k hash functions producing a ≤64-bit code.
pub trait HashFamily: Send + Sync {
    /// Short identifier used in reports ("AH", "EH", "BH", "LBH").
    fn name(&self) -> &'static str;

    /// Total code bits (AH emits 2 bits per hash function).
    fn bits(&self) -> usize;

    /// Encode a database point.
    fn encode_point(&self, x: FeatRef<'_>) -> u64;

    /// Encode a hyperplane query with normal `w`, returning the code to
    /// *look up* — the family-specific sign flips are already applied, so
    /// informative (small-α) points land at small Hamming distance.
    fn encode_query(&self, w: &[f32]) -> u64;

    /// Per-bit confidence of the query encoding: the pre-sign score
    /// magnitude `|s_j|` of each bit, used by the online probe planner to
    /// flip low-confidence bits first (query-directed multi-probe, in the
    /// spirit of Lv et al.). `None` means the family exposes no natural
    /// score and the planner falls back to uniform per-bit costs.
    fn query_bit_scores(&self, _w: &[f32]) -> Option<Vec<f32>> {
        None
    }

    /// Encode every row of a feature store (native CPU path; the PJRT
    /// batch path in `crate::runtime` produces identical codes).
    fn encode_all(&self, feats: &crate::data::FeatureStore) -> codes::CodeArray {
        self.encode_all_pool(feats, &Pool::serial())
    }

    /// Data-parallel batch encode: [`ENCODE_CHUNK`]-row blocks fanned out
    /// over `pool`, bit-identical to [`Self::encode_all`] for any worker
    /// count (rows are independent and reassembled in block order).
    fn encode_all_pool(&self, feats: &crate::data::FeatureStore, pool: &Pool) -> codes::CodeArray {
        let blocks = pool.map(feats.len(), ENCODE_CHUNK, |range| {
            range.map(|i| self.encode_point(feats.row(i))).collect::<Vec<u64>>()
        });
        let mut arr = codes::CodeArray::with_capacity(self.bits(), feats.len());
        for b in blocks {
            arr.codes.extend_from_slice(&b);
        }
        arr
    }
}

/// k pairs of projection vectors (u_j, v_j) — the parameterization shared
/// by AH, BH and LBH. Rows of `u`/`v` are the projections.
#[derive(Clone, Debug)]
pub struct ProjectionPairs {
    pub u: Mat,
    pub v: Mat,
}

impl ProjectionPairs {
    /// iid standard Gaussian pairs — the randomized construction (eq. 7).
    pub fn sample(dim: usize, k: usize, rng: &mut Rng) -> Self {
        let u = Mat::from_vec(k, dim, rng.gauss_vec(k * dim));
        let v = Mat::from_vec(k, dim, rng.gauss_vec(k * dim));
        ProjectionPairs { u, v }
    }

    pub fn k(&self) -> usize {
        self.u.rows
    }

    pub fn dim(&self) -> usize {
        self.u.cols
    }

    /// Per-function projections (uᵀx, vᵀx) for all j.
    #[inline]
    pub fn project(&self, x: FeatRef<'_>) -> (Vec<f32>, Vec<f32>) {
        let k = self.k();
        let mut pu = Vec::with_capacity(k);
        let mut pv = Vec::with_capacity(k);
        for j in 0..k {
            pu.push(x.dot(self.u.row(j)));
            pv.push(x.dot(self.v.row(j)));
        }
        (pu, pv)
    }

    /// Symmetric max-abs i8 quantization of both projection matrices —
    /// the [`QuantizedPairs`] fast path for bandwidth-bound batch
    /// encodes (`--quantized`).
    pub fn quantize(&self) -> QuantizedPairs {
        QuantizedPairs::from_pairs(self)
    }
}

// ─────────────────────── quantized projections ───────────────────────

/// i8-quantized projection pairs: the optional memory-bandwidth fast
/// path for *batch* encodes, gated behind `ExperimentConfig::quantized`
/// / `chh encode --quantized`.
///
/// Each projection row is quantized symmetrically (`q = round(127·w/max|w|)`),
/// and each input row likewise at encode time; dots accumulate in i32
/// and the bilinear product in i64. All quantization scales are
/// positive, so they never change the sign of the product — the encode
/// approximates `sgn((uᵀx)(vᵀx))` directly, and bits only differ from
/// the f32 path where rounding flips a near-zero projection. That makes
/// the path **approximate**: it is deterministic (pure function of the
/// input, chunked identically for any worker count) but NOT bit-identical
/// to [`bilinear_encode`], so it is excluded from every parity-pinned
/// serving path — serving indexes, WAL replay, and replicas always
/// encode in f32. See `docs/PERF.md` for the caveats.
#[derive(Clone, Debug)]
pub struct QuantizedPairs {
    k: usize,
    dim: usize,
    /// k rows × dim, row-major.
    qu: Vec<i8>,
    qv: Vec<i8>,
}

/// Quantize one f32 row symmetrically into `out` (len = row len).
fn quantize_row_i8(row: &[f32], out: &mut [i8]) {
    let max = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if max == 0.0 {
        out.fill(0);
        return;
    }
    let s = 127.0 / max;
    for (o, &v) in out.iter_mut().zip(row.iter()) {
        *o = (v * s).round().clamp(-127.0, 127.0) as i8;
    }
}

/// i32 dot of two i8 slices (≤ 2^24 per dim step — no overflow below
/// dim ≈ 130k).
#[inline]
fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0i32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] as i32 * b[i] as i32;
        acc[1] += a[i + 1] as i32 * b[i + 1] as i32;
        acc[2] += a[i + 2] as i32 * b[i + 2] as i32;
        acc[3] += a[i + 3] as i32 * b[i + 3] as i32;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] as i32 * b[i] as i32;
    }
    s
}

impl QuantizedPairs {
    pub fn from_pairs(pairs: &ProjectionPairs) -> Self {
        let (k, dim) = (pairs.k(), pairs.dim());
        let mut qu = vec![0i8; k * dim];
        let mut qv = vec![0i8; k * dim];
        for j in 0..k {
            quantize_row_i8(pairs.u.row(j), &mut qu[j * dim..(j + 1) * dim]);
            quantize_row_i8(pairs.v.row(j), &mut qv[j * dim..(j + 1) * dim]);
        }
        QuantizedPairs { k, dim, qu, qv }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Quantized encode of one already-densified row (scratch: `qx` holds
    /// the quantized input, `dense` the scattered row for sparse stores).
    fn encode_dense_row(&self, row: &[f32], qx: &mut [i8]) -> u64 {
        quantize_row_i8(row, qx);
        let mut c = 0u64;
        for j in 0..self.k {
            let pu = dot_i8(qx, &self.qu[j * self.dim..(j + 1) * self.dim]) as i64;
            let pv = dot_i8(qx, &self.qv[j * self.dim..(j + 1) * self.dim]) as i64;
            if pu * pv >= 0 {
                c |= 1u64 << j;
            }
        }
        c
    }

    /// Approximate batch encode (see the type docs). [`ENCODE_CHUNK`]
    /// blocks over `pool`; deterministic and pool-parity-identical, but
    /// only sign-approximate vs the f32 path.
    pub fn encode_all_pool(
        &self,
        feats: &crate::data::FeatureStore,
        pool: &Pool,
    ) -> codes::CodeArray {
        let dim = self.dim;
        let blocks: Vec<Vec<u64>> = pool.map(feats.len(), ENCODE_CHUNK, |range| {
            let mut out = Vec::with_capacity(range.len());
            let mut qx = vec![0i8; dim];
            let mut dense = vec![0.0f32; dim];
            for i in range {
                match feats.row(i) {
                    FeatRef::Dense(row) => out.push(self.encode_dense_row(row, &mut qx)),
                    sparse => {
                        dense.fill(0.0);
                        sparse.scatter_into(&mut dense);
                        out.push(self.encode_dense_row(&dense, &mut qx));
                    }
                }
            }
            out
        });
        let mut arr = codes::CodeArray::with_capacity(self.k, feats.len());
        for b in blocks {
            arr.codes.extend_from_slice(&b);
        }
        arr
    }
}

// ───────────────────────────── BH-Hash ─────────────────────────────

/// Randomized Bilinear-Hyperplane Hash (the paper's eq. 7 family B).
#[derive(Clone, Debug)]
pub struct BhHash {
    pub pairs: ProjectionPairs,
}

impl BhHash {
    pub fn sample(dim: usize, k: usize, rng: &mut Rng) -> Self {
        assert!((1..=64).contains(&k));
        BhHash { pairs: ProjectionPairs::sample(dim, k, rng) }
    }

    pub fn from_pairs(pairs: ProjectionPairs) -> Self {
        BhHash { pairs }
    }
}

/// Shared bilinear encode: bit j = [ (u_jᵀx)(v_jᵀx) ≥ 0 ].
#[inline]
fn bilinear_encode(pairs: &ProjectionPairs, x: FeatRef<'_>) -> u64 {
    let (pu, pv) = pairs.project(x);
    let prods: Vec<f32> = pu.iter().zip(pv.iter()).map(|(a, b)| a * b).collect();
    pack_signs(&prods)
}

/// Pre-sign bilinear score magnitudes |(u_jᵀw)(w ᵀv_j)| of a query — the
/// bit-flip confidence shared by BH and LBH.
fn bilinear_query_scores(pairs: &ProjectionPairs, w: &[f32]) -> Vec<f32> {
    let (pu, pv) = pairs.project(FeatRef::Dense(w));
    pu.iter().zip(pv.iter()).map(|(a, b)| (a * b).abs()).collect()
}

/// Batch bilinear encode. Dense stores go through the cache-blocked
/// projection GEMM [`crate::linalg::project_block`]: a
/// [`crate::linalg::GEMM_BIT_BLOCK`]-row slab of each projection matrix
/// is reused across [`crate::linalg::GEMM_ROW_BLOCK`] data rows, so U/V
/// stream from memory once per row block instead of once per row.
/// Every pre-sign entry is computed by the *same* unrolled
/// [`crate::linalg::dot`] in the same operand order as the per-point
/// [`bilinear_encode`] reference, so the batch codes are bit-identical
/// to the scalar path by construction (the earlier axpy-accumulated
/// GEMM only agreed on signs empirically; the blocked kernel agrees on
/// every pre-sign bit pattern). [`ENCODE_CHUNK`]-row blocks fan out over
/// `pool`; rows are independent, so any worker count is bit-identical to
/// serial. Sparse stores keep the per-point sparse-dot path, chunked
/// the same way.
fn bilinear_encode_all(
    pairs: &ProjectionPairs,
    feats: &crate::data::FeatureStore,
    pool: &Pool,
) -> codes::CodeArray {
    use crate::linalg::{project_block, GEMM_ROW_BLOCK};
    let k = pairs.k();
    let blocks: Vec<Vec<u64>> = match feats {
        crate::data::FeatureStore::Dense(x) => pool.map(x.rows, ENCODE_CHUNK, |range| {
            let mut out = Vec::with_capacity(range.len());
            let mut pu = vec![0.0f32; GEMM_ROW_BLOCK * k];
            let mut pv = vec![0.0f32; GEMM_ROW_BLOCK * k];
            let mut scores = vec![0.0f32; k];
            let mut r0 = range.start;
            while r0 < range.end {
                let nb = (range.end - r0).min(GEMM_ROW_BLOCK);
                project_block(x, r0, nb, &pairs.u, &mut pu[..nb * k]);
                project_block(x, r0, nb, &pairs.v, &mut pv[..nb * k]);
                for r in 0..nb {
                    let (ru, rv) = (&pu[r * k..r * k + k], &pv[r * k..r * k + k]);
                    for ((s, &a), &b) in scores.iter_mut().zip(ru.iter()).zip(rv.iter()) {
                        *s = a * b;
                    }
                    out.push(pack_signs(&scores));
                }
                r0 += nb;
            }
            out
        }),
        _ => pool.map(feats.len(), ENCODE_CHUNK, |range| {
            range.map(|i| bilinear_encode(pairs, feats.row(i))).collect()
        }),
    };
    let mut arr = codes::CodeArray::with_capacity(k, feats.len());
    for b in blocks {
        arr.codes.extend_from_slice(&b);
    }
    arr
}

impl HashFamily for BhHash {
    fn name(&self) -> &'static str {
        "BH"
    }

    fn bits(&self) -> usize {
        self.pairs.k()
    }

    fn encode_point(&self, x: FeatRef<'_>) -> u64 {
        bilinear_encode(&self.pairs, x)
    }

    /// h(P_w) = −h(w): the lookup code is the bitwise flip (§3.3).
    fn encode_query(&self, w: &[f32]) -> u64 {
        flip(bilinear_encode(&self.pairs, FeatRef::Dense(w)), self.bits())
    }

    fn query_bit_scores(&self, w: &[f32]) -> Option<Vec<f32>> {
        Some(bilinear_query_scores(&self.pairs, w))
    }

    fn encode_all_pool(&self, feats: &crate::data::FeatureStore, pool: &Pool) -> codes::CodeArray {
        bilinear_encode_all(&self.pairs, feats, pool)
    }
}

// ───────────────────────────── LBH-Hash ─────────────────────────────

/// Learned bilinear hash (§4) — same form as BH with trained projections.
#[derive(Clone, Debug)]
pub struct LbhHash {
    pub pairs: ProjectionPairs,
}

impl LbhHash {
    pub fn from_pairs(pairs: ProjectionPairs) -> Self {
        LbhHash { pairs }
    }
}

impl HashFamily for LbhHash {
    fn name(&self) -> &'static str {
        "LBH"
    }

    fn bits(&self) -> usize {
        self.pairs.k()
    }

    fn encode_point(&self, x: FeatRef<'_>) -> u64 {
        bilinear_encode(&self.pairs, x)
    }

    fn encode_query(&self, w: &[f32]) -> u64 {
        flip(bilinear_encode(&self.pairs, FeatRef::Dense(w)), self.bits())
    }

    fn query_bit_scores(&self, w: &[f32]) -> Option<Vec<f32>> {
        Some(bilinear_query_scores(&self.pairs, w))
    }

    fn encode_all_pool(&self, feats: &crate::data::FeatureStore, pool: &Pool) -> codes::CodeArray {
        bilinear_encode_all(&self.pairs, feats, pool)
    }
}

// ───────────────────────────── AH-Hash ─────────────────────────────

/// Angle-Hyperplane Hash (Jain et al., eq. 2): each hash function emits
/// TWO bits, `[sgn(uᵀz), sgn(vᵀz)]` for points and `[sgn(uᵀz), sgn(−vᵀz)]`
/// for hyperplane normals.
#[derive(Clone, Debug)]
pub struct AhHash {
    pub pairs: ProjectionPairs,
}

impl AhHash {
    /// `k` dual-bit functions ⇒ `2k` code bits.
    pub fn sample(dim: usize, k: usize, rng: &mut Rng) -> Self {
        assert!((1..=32).contains(&k));
        AhHash { pairs: ProjectionPairs::sample(dim, k, rng) }
    }

    pub fn from_pairs(pairs: ProjectionPairs) -> Self {
        assert!(pairs.k() <= 32);
        AhHash { pairs }
    }

    fn encode_raw(&self, x: FeatRef<'_>) -> u64 {
        let (pu, pv) = self.pairs.project(x);
        let mut c = 0u64;
        for j in 0..self.pairs.k() {
            if pu[j] >= 0.0 {
                c |= 1u64 << (2 * j);
            }
            if pv[j] >= 0.0 {
                c |= 1u64 << (2 * j + 1);
            }
        }
        c
    }
}

impl HashFamily for AhHash {
    fn name(&self) -> &'static str {
        "AH"
    }

    fn bits(&self) -> usize {
        2 * self.pairs.k()
    }

    fn encode_point(&self, x: FeatRef<'_>) -> u64 {
        self.encode_raw(x)
    }

    /// Flip the v-bit of every pair: sgn(−vᵀw) = ¬sgn(vᵀw) a.s.
    fn encode_query(&self, w: &[f32]) -> u64 {
        let raw = self.encode_raw(FeatRef::Dense(w));
        let odd_mask = {
            // bits 1,3,5,… within 2k bits
            let mut m = 0u64;
            for j in 0..self.pairs.k() {
                m |= 1u64 << (2 * j + 1);
            }
            m
        };
        raw ^ odd_mask
    }
}

// ───────────────────────────── EH-Hash ─────────────────────────────

/// Embedding-Hyperplane Hash (Jain et al., eq. 4): bit j is
/// `sgn(Σ_{a,b} G_j[a,b]·z_a·z_b) = sgn(zᵀ G_j z)` — a Gaussian functional
/// of the rank-one embedding `vec(zzᵀ)`; hyperplane queries use the
/// negated embedding. `EhHash::full` materializes all d² weights (exact,
/// for theory validation at small d); `EhHash::sampled` implements the
/// paper's dimension-sampling acceleration with `s ≪ d²` sampled
/// coordinates per bit.
#[derive(Clone, Debug)]
pub struct EhHash {
    dim: usize,
    k: usize,
    /// per bit: sampled coordinate pairs of vec(zzᵀ)
    pairs_ab: Vec<Vec<(u32, u32)>>,
    /// per bit: Gaussian weights for each sampled pair
    weights: Vec<Vec<f32>>,
}

impl EhHash {
    /// Exact EH: every (a,b) coordinate with iid N(0,1) weight.
    pub fn full(dim: usize, k: usize, rng: &mut Rng) -> Self {
        assert!((1..=64).contains(&k));
        let mut pairs_ab = Vec::with_capacity(k);
        let mut weights = Vec::with_capacity(k);
        for _ in 0..k {
            let mut ab = Vec::with_capacity(dim * dim);
            let mut ws = Vec::with_capacity(dim * dim);
            for a in 0..dim as u32 {
                for b in 0..dim as u32 {
                    ab.push((a, b));
                    ws.push(rng.gauss_f32());
                }
            }
            pairs_ab.push(ab);
            weights.push(ws);
        }
        EhHash { dim, k, pairs_ab, weights }
    }

    /// Dimension-sampled EH: s random coordinates of vec(zzᵀ) per bit.
    /// With the Gaussian weights rescaled by √(d²/s) the estimator of
    /// `Uᵀvec(zzᵀ)` is unbiased (the rescale does not change the sign, but
    /// keeps score magnitudes comparable across s).
    pub fn sampled(dim: usize, k: usize, s: usize, rng: &mut Rng) -> Self {
        assert!((1..=64).contains(&k));
        assert!(s >= 1);
        let scale = ((dim * dim) as f32 / s as f32).sqrt();
        let mut pairs_ab = Vec::with_capacity(k);
        let mut weights = Vec::with_capacity(k);
        for _ in 0..k {
            let mut ab = Vec::with_capacity(s);
            let mut ws = Vec::with_capacity(s);
            for _ in 0..s {
                ab.push((rng.below(dim) as u32, rng.below(dim) as u32));
                ws.push(rng.gauss_f32() * scale);
            }
            pairs_ab.push(ab);
            weights.push(ws);
        }
        EhHash { dim, k, pairs_ab, weights }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Pre-sign score of bit j: Σ g·z_a·z_b.
    fn score(&self, j: usize, x: FeatRef<'_>) -> f32 {
        let mut s = 0.0f32;
        for (&(a, b), &g) in self.pairs_ab[j].iter().zip(self.weights[j].iter()) {
            s += g * x.coord(a as usize) * x.coord(b as usize);
        }
        s
    }

    /// Dense fast path: scores via cached coordinate reads.
    fn encode_raw(&self, x: FeatRef<'_>) -> u64 {
        let scores: Vec<f32> = (0..self.k).map(|j| self.score(j, x)).collect();
        pack_signs(&scores)
    }
}

impl HashFamily for EhHash {
    fn name(&self) -> &'static str {
        "EH"
    }

    fn bits(&self) -> usize {
        self.k
    }

    fn encode_point(&self, x: FeatRef<'_>) -> u64 {
        self.encode_raw(x)
    }

    /// sgn(−Uᵀvec(wwᵀ)) = flip of the point encoding.
    fn encode_query(&self, w: &[f32]) -> u64 {
        flip(self.encode_raw(FeatRef::Dense(w)), self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::codes::hamming;
    use crate::testing::{forall, pair_with_angle, unit_vec};

    #[test]
    fn bh_scale_invariant() {
        // z and βz (β ≠ 0, either sign) share the point-to-hyperplane
        // angle; the bilinear form squares β so codes must match (§3.2
        // requirement 1).
        forall("bh scale invariance", 64, |rng| {
            let d = rng.range(4, 64);
            let bh = BhHash::sample(d, 16, rng);
            let x = rng.gauss_vec(d);
            let beta = (rng.f32() - 0.5) * 10.0;
            if beta.abs() < 1e-3 {
                return Ok(());
            }
            let xs: Vec<f32> = x.iter().map(|v| v * beta).collect();
            crate::prop_assert!(
                bh.encode_point(FeatRef::Dense(&x)) == bh.encode_point(FeatRef::Dense(&xs)),
                "codes differ under scale {beta}"
            );
            Ok(())
        });
    }

    #[test]
    fn bh_is_xnor_of_ah() {
        // §3.3: "BH-Hash actually performs the XNOR operation over the two
        // bits that AH-Hash outputs".
        forall("bh = xnor(ah)", 64, |rng| {
            let d = rng.range(4, 48);
            let pairs = ProjectionPairs::sample(d, 8, rng);
            let ah = AhHash::from_pairs(pairs.clone());
            let bh = BhHash::from_pairs(pairs);
            let x = rng.gauss_vec(d);
            let ca = ah.encode_point(FeatRef::Dense(&x));
            let cb = bh.encode_point(FeatRef::Dense(&x));
            for j in 0..8 {
                let b_u = (ca >> (2 * j)) & 1;
                let b_v = (ca >> (2 * j + 1)) & 1;
                let xnor = 1 - (b_u ^ b_v);
                crate::prop_assert!(
                    (cb >> j) & 1 == xnor,
                    "bit {j}: ah=({b_u},{b_v}) bh={}",
                    (cb >> j) & 1
                );
            }
            Ok(())
        });
    }

    #[test]
    fn bh_query_is_flip() {
        let mut rng = Rng::seed_from_u64(3);
        let bh = BhHash::sample(16, 20, &mut rng);
        let w = unit_vec(&mut rng, 16);
        let q = bh.encode_query(&w);
        let p = bh.encode_point(FeatRef::Dense(&w));
        assert_eq!(hamming(q, p, 20), 20);
    }

    #[test]
    fn parallel_point_never_collides_bilinear() {
        // x ∥ w ⇒ h(x) = h(w) = flip(query) ⇒ Hamming distance = k for
        // every draw: parallel (uninformative) points are maximally far.
        forall("parallel maximally distant", 32, |rng| {
            let d = rng.range(4, 64);
            let bh = BhHash::sample(d, 12, rng);
            let w = unit_vec(rng, d);
            let x: Vec<f32> = w.iter().map(|v| v * -3.5).collect();
            let dist = hamming(bh.encode_query(&w), bh.encode_point(FeatRef::Dense(&x)), 12);
            crate::prop_assert!(dist == 12, "distance {dist}");
            Ok(())
        });
    }

    #[test]
    fn ah_query_flips_only_v_bits() {
        let mut rng = Rng::seed_from_u64(5);
        let ah = AhHash::sample(24, 8, &mut rng);
        let w = unit_vec(&mut rng, 24);
        let p = ah.encode_point(FeatRef::Dense(&w));
        let q = ah.encode_query(&w);
        let diff = p ^ q;
        for j in 0..8 {
            assert_eq!((diff >> (2 * j)) & 1, 0, "u-bit {j} must not flip");
            assert_eq!((diff >> (2 * j + 1)) & 1, 1, "v-bit {j} must flip");
        }
    }

    #[test]
    fn eh_query_is_flip_and_scale_invariant() {
        let mut rng = Rng::seed_from_u64(7);
        let eh = EhHash::full(12, 10, &mut rng);
        let w = unit_vec(&mut rng, 12);
        assert_eq!(
            hamming(eh.encode_query(&w), eh.encode_point(FeatRef::Dense(&w)), 10),
            10
        );
        let ws: Vec<f32> = w.iter().map(|v| v * -2.0).collect();
        assert_eq!(
            eh.encode_point(FeatRef::Dense(&w)),
            eh.encode_point(FeatRef::Dense(&ws))
        );
    }

    #[test]
    fn sparse_dense_encode_agree() {
        use crate::sparse::CsrBuilder;
        forall("sparse == dense encode", 32, |rng| {
            let d = rng.range(8, 64);
            let bh = BhHash::sample(d, 16, rng);
            let ah = AhHash::sample(d, 8, rng);
            let eh = EhHash::sampled(d, 8, 64, rng);
            // random sparse vector
            let nnz = rng.range(1, d);
            let idx = rng.sample_indices(d, nnz);
            let mut dense = vec![0.0f32; d];
            let mut entries: Vec<(u32, f32)> = Vec::new();
            for &i in &idx {
                let v = rng.gauss_f32();
                dense[i] = v;
                entries.push((i as u32, v));
            }
            let mut b = CsrBuilder::new(d);
            b.push_row(&mut entries);
            let csr = b.finish();
            let sp = FeatRef::Sparse(csr.row(0));
            let dn = FeatRef::Dense(&dense);
            crate::prop_assert!(bh.encode_point(sp) == bh.encode_point(dn), "bh");
            crate::prop_assert!(ah.encode_point(sp) == ah.encode_point(dn), "ah");
            crate::prop_assert!(eh.encode_point(sp) == eh.encode_point(dn), "eh");
            Ok(())
        });
    }

    #[test]
    fn informative_points_closer_than_uninformative() {
        // Statistical sanity: on average over random draws, a perpendicular
        // point lands closer to the query code than a 30°-from-parallel
        // point (monotone collision probability).
        let mut rng = Rng::seed_from_u64(11);
        let d = 32;
        let k = 24;
        let trials = 200;
        let mut d_perp = 0u64;
        let mut d_par = 0u64;
        for _ in 0..trials {
            let bh = BhHash::sample(d, k, &mut rng);
            let (w, x_perp) = pair_with_angle(&mut rng, d, std::f32::consts::FRAC_PI_2);
            let q = bh.encode_query(&w);
            d_perp += hamming(q, bh.encode_point(FeatRef::Dense(&x_perp)), k) as u64;
            let (w2, x_par) = pair_with_angle(&mut rng, d, 0.5); // θ=0.5 rad from w
            let q2 = bh.encode_query(&w2);
            d_par += hamming(q2, bh.encode_point(FeatRef::Dense(&x_par)), k) as u64;
        }
        assert!(
            d_perp < d_par,
            "perp total {d_perp} should be < near-parallel total {d_par}"
        );
    }

    #[test]
    fn query_bit_scores_are_presign_magnitudes() {
        let mut rng = Rng::seed_from_u64(17);
        let bh = BhHash::sample(24, 14, &mut rng);
        let w = unit_vec(&mut rng, 24);
        let scores = bh.query_bit_scores(&w).expect("BH exposes scores");
        assert_eq!(scores.len(), 14);
        assert!(scores.iter().all(|s| *s >= 0.0), "magnitudes are non-negative");
        // consistency: sign of the raw bilinear product must reproduce the
        // (pre-flip) point encoding of w
        let (pu, pv) = bh.pairs.project(FeatRef::Dense(&w));
        let point = bh.encode_point(FeatRef::Dense(&w));
        for j in 0..14 {
            let prod = pu[j] * pv[j];
            assert!((prod.abs() - scores[j]).abs() < 1e-6, "bit {j}");
            let bit = (point >> j) & 1;
            assert_eq!(bit == 1, prod >= 0.0, "bit {j} sign");
        }
        // EH keeps the uniform fallback
        let eh = EhHash::sampled(24, 8, 32, &mut rng);
        assert!(eh.query_bit_scores(&w).is_none());
    }

    #[test]
    fn encode_all_matches_pointwise() {
        let mut rng = Rng::seed_from_u64(13);
        let ds = crate::data::test_blobs(50, 16, 3, &mut rng);
        let bh = BhHash::sample(16, 12, &mut rng);
        let arr = bh.encode_all(ds.features());
        assert_eq!(arr.len(), 50);
        for i in 0..50 {
            assert_eq!(arr.get(i), bh.encode_point(ds.features().row(i)));
        }
    }

    #[test]
    fn quantized_encode_deterministic_pool_parity_and_close() {
        // the quantized path is approximate vs f32 but must be (a) a pure
        // function of its input, (b) bit-identical across worker counts,
        // (c) in high per-bit agreement with the exact encode
        let mut rng = Rng::seed_from_u64(23);
        let ds = crate::data::test_blobs(800, 32, 4, &mut rng);
        let bh = BhHash::sample(32, 20, &mut rng);
        let q = bh.pairs.quantize();
        let exact = bh.encode_all(ds.features());
        let a = q.encode_all_pool(ds.features(), &Pool::serial());
        let b = q.encode_all_pool(ds.features(), &Pool::serial());
        assert_eq!(a.codes, b.codes, "quantized encode not deterministic");
        for w in [2usize, 3, 4] {
            let p = q.encode_all_pool(ds.features(), &Pool::new(w));
            assert_eq!(p.codes, a.codes, "quantized pool parity workers={w}");
        }
        let total_bits = (a.len() * 20) as f64;
        let agree: u32 = a
            .codes
            .iter()
            .zip(exact.codes.iter())
            .map(|(&x, &y)| 20 - hamming(x, y, 20))
            .sum();
        let rate = agree as f64 / total_bits;
        assert!(rate >= 0.85, "per-bit agreement {rate:.3} below 0.85");
    }

    #[test]
    fn quantized_encode_handles_sparse_and_zero_rows() {
        use crate::data::{newsgroups_like, NewsConfig};
        let mut rng = Rng::seed_from_u64(29);
        let ds = newsgroups_like(
            &NewsConfig { n: 300, vocab: 128, classes: 4, ..Default::default() },
            &mut rng,
        );
        let bh = BhHash::sample(128, 16, &mut rng);
        let q = bh.pairs.quantize();
        let arr = q.encode_all_pool(ds.features(), &Pool::serial());
        assert_eq!(arr.len(), 300);
        // all-zero input row quantizes to all-zero ⇒ every product is 0
        // and every bit packs to +1 (sgn(0) = +1), matching the f32 path
        let zero = vec![0.0f32; 128];
        let store = crate::data::FeatureStore::Dense(Mat::from_vec(1, 128, zero.clone()));
        let qa = q.encode_all_pool(&store, &Pool::serial());
        assert_eq!(qa.get(0), bh.encode_point(FeatRef::Dense(&zero)));
        assert_eq!(qa.get(0), codes::mask(16));
    }

    // encode_all_pool parity across families, store layouts and worker
    // counts is covered by the integration suite in
    // rust/tests/batch_parallel.rs, and kernel-vs-scalar bit parity by
    // rust/tests/kernel_parity.rs.
}
