//! Multiply-shift hasher for u64 hash-code keys.
//!
//! `std::collections::HashMap` defaults to SipHash-1-3 (~15–20 ns per u64
//! key); bucket probing enumerates thousands of ball keys per query, so
//! the hasher is squarely on the hot path. Codes are already uniformly
//! distributed bit patterns, so a single Fibonacci-style multiply plus a
//! xor-fold is collision-adequate and ~4× faster (§Perf pass; before/after
//! in EXPERIMENTS.md).

use std::hash::{BuildHasher, Hasher};

/// Hasher state: fold the (single) u64 write through a multiply.
#[derive(Clone, Default)]
pub struct CodeHasher {
    state: u64,
}

impl Hasher for CodeHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // generic path (not used for u64 keys, kept correct anyway)
        for &b in bytes {
            self.state = (self.state ^ b as u64).wrapping_mul(0x100_0000_01B3);
        }
    }

    #[inline]
    fn write_u64(&mut self, x: u64) {
        // golden-ratio multiply then xor-fold the high bits down so that
        // HashMap's low-bit masking sees the mixed entropy
        let h = x.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        self.state = h ^ (h >> 32);
    }
}

/// BuildHasher for [`CodeHasher`].
#[derive(Clone, Default)]
pub struct CodeHashBuilder;

impl BuildHasher for CodeHashBuilder {
    type Hasher = CodeHasher;

    #[inline]
    fn build_hasher(&self) -> CodeHasher {
        CodeHasher::default()
    }
}

/// HashMap keyed by hash codes with the fast hasher.
pub type CodeMap<V> = std::collections::HashMap<u64, V, CodeHashBuilder>;

/// Approximate heap bytes of a bucket map at allocated capacity: per
/// slot the u64 key, the `Vec` header and a control byte, plus every
/// bucket's id payload at its allocated capacity. Counting capacities
/// rather than lengths keeps the accounting honest under `Vec` growth
/// doubling. The one formula shared by [`crate::table::HyperplaneIndex`],
/// [`crate::table::LshIndex`] and the online shards — their memory
/// comparisons are only meaningful while they agree on it.
pub fn bucket_map_bytes(m: &CodeMap<Vec<u32>>) -> usize {
    m.capacity() * (8 + std::mem::size_of::<Vec<u32>>() + 1)
        + m.values().map(|v| v.capacity() * 4).sum::<usize>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrip() {
        let mut m: CodeMap<u32> = CodeMap::default();
        for i in 0..10_000u64 {
            m.insert(i * 0x1234_5678_9ABC ^ i, i as u32);
        }
        for i in 0..10_000u64 {
            assert_eq!(m.get(&(i * 0x1234_5678_9ABC ^ i)), Some(&(i as u32)));
        }
        assert_eq!(m.len(), 10_000);
    }

    #[test]
    fn distinct_inputs_distinct_hashes_mostly() {
        // sanity: low-bit distribution of hashed sequential codes is flat
        let b = CodeHashBuilder;
        let mut buckets = [0usize; 64];
        for code in 0..64_000u64 {
            let mut h = b.build_hasher();
            h.write_u64(code);
            buckets[(h.finish() & 63) as usize] += 1;
        }
        let expect = 1000.0;
        for &c in &buckets {
            assert!((c as f64 - expect).abs() < 0.2 * expect, "{buckets:?}");
        }
    }
}
