//! Hash-code bit manipulation.
//!
//! Codes are at most 64 bits (the paper's compact regime is k ≤ ~40 even
//! for the dual-bit AH-Hash), so a code is a single `u64` with the low
//! `k` bits meaningful. Hamming distance is one XOR + POPCNT.

/// Mask with the low k bits set.
///
/// Hard-asserts `1 ≤ k ≤ 64` even in release builds: with only a
/// `debug_assert`, `mask(65)` would wrap the shift and silently return
/// `1`, poisoning every masked scan downstream. The callers that sit on
/// per-element hot paths ([`CodeArray::hamming_scan`],
/// `table::rank_search`) hoist the mask out of their loops, so the check
/// runs once per scan, not once per code.
#[inline]
pub fn mask(k: usize) -> u64 {
    assert!(k >= 1 && k <= 64, "code length {k} out of range");
    if k == 64 {
        u64::MAX
    } else {
        (1u64 << k) - 1
    }
}

/// Hamming distance between two k-bit codes.
#[inline]
pub fn hamming(a: u64, b: u64, k: usize) -> u32 {
    ((a ^ b) & mask(k)).count_ones()
}

/// Bitwise NOT restricted to the low k bits (the paper's query-side flip:
/// search near `~H(w)` ⇔ farthest codes from `H(w)`).
#[inline]
pub fn flip(code: u64, k: usize) -> u64 {
    !code & mask(k)
}

/// Pack a ±1 (or arbitrary-sign) score slice into bits: bit j = 1 iff
/// scores[j] >= 0 — `sgn` with the paper's convention sgn(0) = +1.
///
/// # Precondition: finite scores
///
/// Scores must not be NaN. `NaN >= 0.0` is false, so a NaN score packs
/// as the −1 bit — which breaks the sgn(0) = +1 convention *and* the
/// point/query symmetry the flipped lookup relies on (both sides of a
/// NaN product would pack to −1 instead of opposite bits). The
/// ingestion layers uphold this: the HTTP server rejects non-finite
/// query hyperplanes with a 400, and [`crate::data::Dataset::new`]
/// rejects non-finite features at store build, so no projection score
/// computed from stored data can be NaN. (±∞ scores are fine: they
/// carry a definite sign.)
#[inline]
pub fn pack_signs(scores: &[f32]) -> u64 {
    debug_assert!(scores.len() <= 64);
    let mut c = 0u64;
    for (j, &s) in scores.iter().enumerate() {
        if s >= 0.0 {
            c |= 1u64 << j;
        }
    }
    c
}

/// Unpack a k-bit code into ±1 floats.
pub fn unpack_pm1(code: u64, k: usize) -> Vec<f32> {
    (0..k).map(|j| if (code >> j) & 1 == 1 { 1.0 } else { -1.0 }).collect()
}

/// Dense ±1 code matrix for k ≤ 64: one u64 word per point.
#[derive(Clone, Debug)]
pub struct CodeArray {
    pub k: usize,
    pub codes: Vec<u64>,
}

impl CodeArray {
    pub fn new(k: usize) -> Self {
        assert!((1..=64).contains(&k));
        CodeArray { k, codes: Vec::new() }
    }

    pub fn with_capacity(k: usize, n: usize) -> Self {
        assert!((1..=64).contains(&k));
        CodeArray { k, codes: Vec::with_capacity(n) }
    }

    pub fn push(&mut self, code: u64) {
        debug_assert_eq!(code & !mask(self.k), 0, "code has bits above k");
        self.codes.push(code);
    }

    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize) -> u64 {
        self.codes[i]
    }

    /// Hamming distances from a query code to every stored code
    /// (the linear-scan "Hamming ranking" mode used when the hash-lookup
    /// ball is empty or for evaluation). Delegates to the chunked
    /// [`hamming_sweep_into`] kernel; `out`'s capacity is reused across
    /// calls, so a scratch vector makes repeated scans allocation-free.
    pub fn hamming_scan(&self, q: u64, out: &mut Vec<u32>) {
        let qm = q & mask(self.k);
        hamming_sweep_into(&self.codes, qm, out);
    }
}

/// Block length of the chunked popcount sweep. 64 u64 words = one 512-byte
/// slab — eight cache lines, far below L1 — so the only tuning concern is
/// giving the autovectorizer a fixed-trip-count inner loop it can unroll
/// into XOR+POPCNT lanes without bounds checks.
pub const SCAN_BLOCK: usize = 64;

/// Chunked XOR+POPCNT sweep: distance from `q_masked` to every code in
/// `codes`, written into `out` (resized to `codes.len()`; existing
/// capacity is reused).
///
/// `q_masked` must already be masked to the array's k bits — callers
/// hoist `& mask(k)` so the per-element loop is a pure `xor` +
/// `count_ones`. Writing into a pre-sized slice (instead of `push`ing)
/// removes the per-element capacity check that blocks
/// autovectorization; the fixed-width [`SCAN_BLOCK`] inner loop lets
/// LLVM emit unrolled popcount lanes. Distances are bit-identical to the
/// obvious scalar loop — the kernel only re-blocks independent
/// per-element work.
pub fn hamming_sweep_into(codes: &[u64], q_masked: u64, out: &mut Vec<u32>) {
    out.clear();
    out.resize(codes.len(), 0);
    let mut cs = codes.chunks_exact(SCAN_BLOCK);
    let mut os = out.chunks_exact_mut(SCAN_BLOCK);
    for (cb, ob) in (&mut cs).zip(&mut os) {
        for i in 0..SCAN_BLOCK {
            ob[i] = (cb[i] ^ q_masked).count_ones();
        }
    }
    for (o, &c) in os.into_remainder().iter_mut().zip(cs.remainder().iter()) {
        *o = (c ^ q_masked).count_ones();
    }
}

/// Iterator over all k-bit masks of Hamming weight ≤ r, in increasing
/// weight order (weight 0 first — the exact bucket). Used to enumerate the
/// Hamming ball around the flipped query code. Total count Σ_{i≤r} C(k,i).
///
/// Uses Gosper's hack (next-bit-permutation) to walk each weight class in
/// a handful of ALU ops per mask — the §Perf pass replaced a Vec-based
/// combination walker with this (≈5× faster enumeration, see
/// EXPERIMENTS.md §Perf).
pub struct HammingBall {
    k: usize,
    r: usize,
    weight: usize,
    /// current mask within the weight class; 0 ⇒ start next weight
    cur: u64,
    limit: u64,
    started: bool,
    done: bool,
}

impl HammingBall {
    pub fn new(k: usize, r: usize) -> Self {
        HammingBall {
            k,
            r: r.min(k),
            weight: 0,
            cur: 0,
            limit: mask(k.max(1)),
            started: false,
            done: false,
        }
    }
}

impl Iterator for HammingBall {
    type Item = u64;

    #[inline]
    fn next(&mut self) -> Option<u64> {
        if self.done {
            return None;
        }
        if !self.started {
            self.started = true;
            return Some(0); // weight 0: the exact bucket
        }
        loop {
            if self.cur == 0 {
                // begin the next weight class with the lowest mask
                self.weight += 1;
                if self.weight > self.r || self.weight > self.k {
                    self.done = true;
                    return None;
                }
                self.cur = mask(self.weight);
                return Some(self.cur);
            }
            // Gosper's hack: next mask with the same popcount
            let v = self.cur;
            let c = v & v.wrapping_neg();
            // When v is the final (top-aligned) mask of a 64-bit weight
            // class, v + c is exactly 2^64: wrap to 0 and treat the class
            // as exhausted. A plain `v + c` would panic in debug builds.
            let r = v.wrapping_add(c);
            let next = if r == 0 { 0 } else { (((v ^ r) >> 2) / c) | r };
            if next == 0 || next > self.limit {
                self.cur = 0; // weight class exhausted; advance weight
                continue;
            }
            self.cur = next;
            return Some(next);
        }
    }
}

/// Binomial coefficient (exact for the small k used here).
pub fn binom(n: usize, k: usize) -> u64 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut num = 1u128;
    let mut den = 1u128;
    for i in 0..k {
        num *= (n - i) as u128;
        den *= (i + 1) as u128;
    }
    (num / den) as u64
}

/// Σ_{i=0..=r} C(k,i) — the Hamming-ball volume.
pub fn ball_volume(k: usize, r: usize) -> u64 {
    (0..=r.min(k)).map(|i| binom(k, i)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    #[test]
    fn mask_and_flip() {
        assert_eq!(mask(4), 0b1111);
        assert_eq!(mask(64), u64::MAX);
        assert_eq!(flip(0b1010, 4), 0b0101);
        assert_eq!(flip(flip(0xABCD, 16), 16), 0xABCD);
    }

    #[test]
    fn mask_boundaries() {
        // both legal extremes, in release as well as debug
        assert_eq!(mask(1), 1);
        assert_eq!(mask(63), u64::MAX >> 1);
        assert_eq!(mask(64), u64::MAX);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn mask_rejects_zero() {
        mask(0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn mask_rejects_above_64() {
        // with only a debug_assert this returned 1 in release (shift wrap)
        mask(65);
    }

    #[test]
    fn hamming_basics() {
        assert_eq!(hamming(0b1010, 0b1010, 4), 0);
        assert_eq!(hamming(0b1010, 0b0101, 4), 4);
        assert_eq!(hamming(0, u64::MAX, 16), 16);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        forall("pack/unpack roundtrip", 64, |rng| {
            let k = rng.range(1, 65);
            let scores: Vec<f32> = (0..k).map(|_| rng.gauss_f32()).collect();
            let code = pack_signs(&scores);
            let pm = unpack_pm1(code, k);
            for (j, (&s, &p)) in scores.iter().zip(pm.iter()).enumerate() {
                let want = if s >= 0.0 { 1.0 } else { -1.0 };
                crate::prop_assert!(p == want, "bit {j}: score {s} pm {p}");
            }
            Ok(())
        });
    }

    #[test]
    fn hamming_is_metric() {
        forall("hamming metric axioms", 128, |rng| {
            let k = rng.range(1, 65);
            let m = mask(k);
            let a = rng.next_u64() & m;
            let b = rng.next_u64() & m;
            let c = rng.next_u64() & m;
            crate::prop_assert!(hamming(a, a, k) == 0, "identity");
            crate::prop_assert!(hamming(a, b, k) == hamming(b, a, k), "symmetry");
            crate::prop_assert!(
                hamming(a, c, k) <= hamming(a, b, k) + hamming(b, c, k),
                "triangle"
            );
            Ok(())
        });
    }

    #[test]
    fn flip_maximizes_distance() {
        forall("flip gives max hamming distance", 64, |rng| {
            let k = rng.range(1, 65);
            let c = rng.next_u64() & mask(k);
            crate::prop_assert!(hamming(c, flip(c, k), k) as usize == k, "flip distance");
            Ok(())
        });
    }

    #[test]
    fn ball_enumeration_complete_and_ordered() {
        forall("ball volume and ordering", 48, |rng| {
            let k = rng.range(1, 22);
            let r = rng.range(0, k.min(5) + 1);
            let masks: Vec<u64> = HammingBall::new(k, r).collect();
            crate::prop_assert!(
                masks.len() as u64 == ball_volume(k, r),
                "count {} vs volume {}",
                masks.len(),
                ball_volume(k, r)
            );
            // distinct
            let set: std::collections::HashSet<_> = masks.iter().collect();
            crate::prop_assert!(set.len() == masks.len(), "duplicates");
            // non-decreasing weight, all ≤ r, all within k bits
            let mut last_w = 0;
            for &m in &masks {
                let w = m.count_ones() as usize;
                crate::prop_assert!(w >= last_w, "weight order");
                crate::prop_assert!(w <= r, "weight bound");
                crate::prop_assert!(m & !mask(k) == 0, "bits above k");
                last_w = w;
            }
            Ok(())
        });
        // The 63/64-bit boundary (bounded radius): the last mask of a
        // weight class is top-aligned there and Gosper's next-permutation
        // addition reaches 2^64 at k = 64 — regression for the wrapping
        // guard in `HammingBall::next`.
        for k in [63usize, 64] {
            for r in 0..=2usize {
                let masks: Vec<u64> = HammingBall::new(k, r).collect();
                assert_eq!(
                    masks.len() as u64,
                    ball_volume(k, r),
                    "k={k} r={r}: enumeration incomplete"
                );
                let set: std::collections::HashSet<_> = masks.iter().collect();
                assert_eq!(set.len(), masks.len(), "k={k} r={r}: duplicates");
                let mut last_w = 0;
                for &m in &masks {
                    let w = m.count_ones() as usize;
                    assert!(w >= last_w && w <= r, "k={k} r={r}: weight order");
                    assert_eq!(m & !mask(k), 0, "k={k} r={r}: bits above k");
                    last_w = w;
                }
            }
        }
    }

    #[test]
    fn ball_weight_classes_have_binomial_counts() {
        // each distance ring inside the ball is complete: exactly C(k,w)
        // masks of weight w, so a prefix of the enumeration is always a
        // union of full rings plus part of the last ring
        forall("ring sizes are binomial", 32, |rng| {
            let k = rng.range(2, 20);
            let r = rng.range(0, k.min(5) + 1);
            let mut per_weight = vec![0u64; r + 1];
            for m in HammingBall::new(k, r) {
                per_weight[m.count_ones() as usize] += 1;
            }
            for (w, &count) in per_weight.iter().enumerate() {
                crate::prop_assert!(
                    count == binom(k, w),
                    "k={k} r={r}: weight {w} has {count} masks, want {}",
                    binom(k, w)
                );
            }
            Ok(())
        });
    }

    #[test]
    fn ball_enumeration_agrees_with_planner_set() {
        // the online planner and the static ball walker must agree on the
        // probe universe for any cost assignment (order may differ)
        forall("ball == planner universe", 16, |rng| {
            let k = rng.range(2, 16);
            let r = rng.range(0, k.min(4) + 1);
            let costs: Vec<f64> = (0..k).map(|_| 0.5 + rng.f64()).collect();
            let planner = crate::online::ProbePlanner::with_costs(k, r, costs);
            let mut a: Vec<u64> = HammingBall::new(k, r).collect();
            let mut b: Vec<u64> = planner.plan(usize::MAX).collect();
            a.sort_unstable();
            b.sort_unstable();
            crate::prop_assert!(a == b, "k={k} r={r}: universes differ");
            Ok(())
        });
    }

    #[test]
    fn ball_radius_zero_is_exact_bucket() {
        let masks: Vec<u64> = HammingBall::new(16, 0).collect();
        assert_eq!(masks, vec![0]);
    }

    #[test]
    fn ball_full_radius_is_power_set() {
        let masks: Vec<u64> = HammingBall::new(5, 5).collect();
        assert_eq!(masks.len(), 32);
    }

    #[test]
    fn binom_table() {
        assert_eq!(binom(20, 0), 1);
        assert_eq!(binom(20, 1), 20);
        assert_eq!(binom(20, 4), 4845);
        assert_eq!(binom(5, 7), 0);
        assert_eq!(ball_volume(20, 4), 1 + 20 + 190 + 1140 + 4845);
    }

    #[test]
    fn hamming_scan_matches_pointwise() {
        let mut arr = CodeArray::new(8);
        for c in [0u64, 0xFF, 0b1010_1010, 0b0101_0101] {
            arr.push(c);
        }
        let mut out = Vec::new();
        arr.hamming_scan(0b1111_0000, &mut out);
        let expect: Vec<u32> =
            arr.codes.iter().map(|&c| hamming(c, 0b1111_0000, 8)).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn hamming_sweep_matches_scalar_loop() {
        // block + remainder shapes, including empty and exactly-one-block
        forall("chunked sweep == scalar", 48, |rng| {
            let k = rng.range(1, 65);
            let n = match rng.range(0, 4) {
                0 => 0,
                1 => rng.range(1, SCAN_BLOCK),
                2 => SCAN_BLOCK,
                _ => rng.range(SCAN_BLOCK + 1, 3 * SCAN_BLOCK + 7),
            };
            let m = mask(k);
            let codes: Vec<u64> = (0..n).map(|_| rng.next_u64() & m).collect();
            let qm = rng.next_u64() & m;
            let mut out = vec![999u32; 3]; // stale contents must be cleared
            hamming_sweep_into(&codes, qm, &mut out);
            let expect: Vec<u32> =
                codes.iter().map(|&c| (c ^ qm).count_ones()).collect();
            crate::prop_assert!(out == expect, "k={k} n={n}");
            Ok(())
        });
    }
}
