//! The serving layer: a hyperplane-query router with batching,
//! leader/worker threads and bounded-queue backpressure.
//!
//! The paper's end application issues one hyperplane query per (class ×
//! AL iteration); a deployment amortizes them by batching the one-vs-all
//! hyperplanes of an iteration (20 on 20NG, 10 on Tiny) into a single
//! encode + fan-out. This module is the L3 "coordinator" piece of the
//! three-layer architecture:
//!
//! ```text
//!            submit(w)                 Job { id, lookup code, w }
//!  caller ──────────────▶ leader ─────────────────────────────▶ workers
//!            (bounded)    encodes (native or PJRT batch)        probe table,
//!  caller ◀────────────── response channel ◀──────────────────  re-rank margins
//! ```
//!
//! The vendored registry has no tokio, so the implementation uses OS
//! threads + `std::sync::mpsc` bounded channels; the public API is
//! synchronous-with-handles (submit returns a ticket, `recv` joins it).
//!
//! Two routers share the job-queue machinery:
//!
//! * [`Router`] — the static path over a prebuilt [`HyperplaneIndex`];
//!   one worker answers one query end to end.
//! * [`OnlineRouter`] — the dynamic path over a
//!   [`crate::online::ShardedIndex`]: every query is split into one job
//!   per shard, workers probe their shard's epoch snapshot with the
//!   query's best-first probe plan, and the last-finishing shard merges
//!   the partial hits and resolves the caller's ticket.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::data::FeatureStore;
use crate::hash::HashFamily;
use crate::online::{merge_hits, QueryBudget, ShardedIndex};
use crate::table::{HyperplaneIndex, QueryHit};

/// A point-to-hyperplane query.
#[derive(Clone, Debug)]
pub struct QueryRequest {
    /// hyperplane normal (dim must match the index's feature store)
    pub w: Vec<f32>,
    /// indices excluded from results (e.g. already-labeled points)
    pub exclude: Option<Arc<HashSet<usize>>>,
}

/// Router answer for one request.
#[derive(Clone, Debug)]
pub struct QueryResponse {
    pub id: u64,
    pub hit: QueryHit,
    /// time from submit to completion
    pub latency: Duration,
}

struct Job {
    id: u64,
    lookup: u64,
    req: QueryRequest,
    submitted: Instant,
    reply: Sender<QueryResponse>,
}

/// Router statistics (atomic, cheap to read while serving).
pub struct RouterStats {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub empty_lookups: AtomicU64,
    pub candidates_scanned: AtomicU64,
    /// recent queued-path latencies (bounded ring — routers are
    /// long-lived, so an unbounded per-query reservoir would leak)
    latencies: Mutex<crate::metrics::Histogram>,
}

impl Default for RouterStats {
    fn default() -> Self {
        RouterStats {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            empty_lookups: AtomicU64::new(0),
            candidates_scanned: AtomicU64::new(0),
            latencies: Mutex::new(crate::metrics::Histogram::with_capacity(
                crate::metrics::SERVING_RESERVOIR,
            )),
        }
    }
}

impl RouterStats {
    pub fn latency_p50(&self) -> f64 {
        self.latencies.lock().unwrap().percentile(50.0)
    }

    pub fn latency_p95(&self) -> f64 {
        self.latencies.lock().unwrap().percentile(95.0)
    }

    pub fn latency_mean(&self) -> f64 {
        self.latencies.lock().unwrap().mean()
    }

    /// Several percentiles with one lock acquisition and one sort —
    /// prefer this over repeated `latency_p*` calls while serving.
    pub fn latency_percentiles(&self, ps: &[f64]) -> Vec<f64> {
        self.latencies.lock().unwrap().percentiles(ps)
    }
}

/// Shared immutable serving state.
struct Shared {
    family: Arc<dyn HashFamily>,
    index: Arc<HyperplaneIndex>,
    feats: Arc<FeatureStore>,
    stats: Arc<RouterStats>,
}

/// The hyperplane-query router.
pub struct Router {
    tx: SyncSender<Job>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
    stats: Arc<RouterStats>,
    shared: Arc<Shared>,
}

/// Ticket for an in-flight query.
pub struct Pending {
    pub id: u64,
    rx: Receiver<QueryResponse>,
}

impl Pending {
    /// Block until the response arrives.
    pub fn wait(self) -> QueryResponse {
        self.rx.recv().expect("router worker dropped the reply channel")
    }
}

impl Router {
    /// Spawn a router over a prebuilt index. `queue_cap` bounds the job
    /// queue — a full queue blocks `submit`, which is the backpressure
    /// mechanism protecting worker latency.
    pub fn new(
        family: Arc<dyn HashFamily>,
        index: Arc<HyperplaneIndex>,
        feats: Arc<FeatureStore>,
        workers: usize,
        queue_cap: usize,
    ) -> Self {
        let stats = Arc::new(RouterStats::default());
        let shared = Arc::new(Shared {
            family,
            index,
            feats,
            stats: stats.clone(),
        });
        let (tx, rx) = sync_channel::<Job>(queue_cap.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers.max(1))
            .map(|_| {
                let rx = rx.clone();
                let sh = shared.clone();
                std::thread::spawn(move || worker_loop(rx, sh))
            })
            .collect();
        Router { tx, workers: handles, next_id: AtomicU64::new(0), stats, shared }
    }

    pub fn stats(&self) -> &RouterStats {
        &self.stats
    }

    /// The hash family queries are encoded with.
    pub fn family(&self) -> &Arc<dyn HashFamily> {
        &self.shared.family
    }

    /// The index this router serves.
    pub fn index(&self) -> &Arc<HyperplaneIndex> {
        &self.shared.index
    }

    /// The serving feature store (margins are ranked against its rows).
    pub fn feats(&self) -> &Arc<FeatureStore> {
        &self.shared.feats
    }

    /// Submit one query; blocks when the queue is full (backpressure).
    /// The hyperplane is encoded on the caller/leader thread so workers
    /// only do table probes + margin re-ranking.
    pub fn submit(&self, req: QueryRequest) -> Pending {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let lookup = self.shared.family.encode_query(&req.w);
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let job = Job { id, lookup, req, submitted: Instant::now(), reply: reply_tx };
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        self.tx.send(job).expect("router workers are gone");
        Pending { id, rx: reply_rx }
    }

    /// Submit a batch (e.g. the one-vs-all hyperplanes of an AL iteration)
    /// and wait for all responses, returned in submission order.
    pub fn submit_batch(&self, reqs: Vec<QueryRequest>) -> Vec<QueryResponse> {
        let pendings: Vec<Pending> = reqs.into_iter().map(|r| self.submit(r)).collect();
        pendings.into_iter().map(|p| p.wait()).collect()
    }

    /// Synchronous data-parallel batch: bypass the job queue and answer
    /// `reqs` directly on `pool` workers (the offline/eval shape of the
    /// workload; the queue stays the serving path). Hits come back in
    /// request order and are bit-identical to looping [`Self::submit`] —
    /// the submitted/completed/scanned counters are updated, latency
    /// percentiles are not (there is no queueing to measure).
    pub fn query_batch_pooled(
        &self,
        reqs: &[QueryRequest],
        pool: &crate::par::Pool,
    ) -> Vec<QueryHit> {
        self.query_batch_pooled_traced(reqs, pool).0
    }

    /// [`Self::query_batch_pooled`] plus the batch's per-stage
    /// wall-clock, summed over requests. The untraced entry point
    /// delegates here, so traced and untraced hits are bit-identical by
    /// construction. On the static index the probe-plan and merge work
    /// is fused into the table scan, so only `encode` and `scan` are
    /// populated.
    pub fn query_batch_pooled_traced(
        &self,
        reqs: &[QueryRequest],
        pool: &crate::par::Pool,
    ) -> (Vec<QueryHit>, crate::obs::StageTimes) {
        let sh = &self.shared;
        let results: Vec<(QueryHit, crate::obs::StageTimes)> = pool
            .map(reqs.len(), crate::table::QUERY_CHUNK, |range| {
                range
                    .map(|qi| {
                        let req = &reqs[qi];
                        let mut st = crate::obs::StageTimes::default();
                        let t0 = Instant::now();
                        let lookup = sh.family.encode_query(&req.w);
                        st.encode = t0.elapsed();
                        let t1 = Instant::now();
                        let hit = match &req.exclude {
                            Some(ex) => sh.index.query_code_filtered(
                                lookup,
                                &req.w,
                                &sh.feats,
                                |i| !ex.contains(&i),
                            ),
                            None => sh
                                .index
                                .query_code_filtered(lookup, &req.w, &sh.feats, |_| true),
                        };
                        st.scan = t1.elapsed();
                        (hit, st)
                    })
                    .collect::<Vec<_>>()
            })
            .into_iter()
            .flatten()
            .collect();
        let mut times = crate::obs::StageTimes::default();
        let mut hits = Vec::with_capacity(results.len());
        for (h, st) in results {
            times.add(&st);
            hits.push(h);
        }
        let scanned: usize = hits.iter().map(|h| h.scanned).sum();
        let empty = hits.iter().filter(|h| !h.nonempty).count();
        self.stats.submitted.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        self.stats.completed.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        self.stats.empty_lookups.fetch_add(empty as u64, Ordering::Relaxed);
        self.stats.candidates_scanned.fetch_add(scanned as u64, Ordering::Relaxed);
        (hits, times)
    }

    /// Drain the queue and join the workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for h in self.workers {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>, sh: Arc<Shared>) {
    // one scratch per worker thread: the candidate gather of every query
    // this worker answers reuses the same buffer (answers are identical
    // to the scratch-free path — see table::QueryScratch)
    let mut scratch = crate::table::QueryScratch::new();
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(j) => j,
                Err(_) => return, // router dropped
            }
        };
        let hit = match &job.req.exclude {
            Some(ex) => sh.index.query_code_filtered_with(
                job.lookup,
                &job.req.w,
                &sh.feats,
                |i| !ex.contains(&i),
                &mut scratch,
            ),
            None => sh.index.query_code_filtered_with(
                job.lookup,
                &job.req.w,
                &sh.feats,
                |_| true,
                &mut scratch,
            ),
        };
        sh.stats.completed.fetch_add(1, Ordering::Relaxed);
        if !hit.nonempty {
            sh.stats.empty_lookups.fetch_add(1, Ordering::Relaxed);
        }
        sh.stats
            .candidates_scanned
            .fetch_add(hit.scanned as u64, Ordering::Relaxed);
        let latency = job.submitted.elapsed();
        sh.stats.latencies.lock().unwrap().record_duration(latency);
        let _ = job.reply.send(QueryResponse { id: job.id, hit, latency });
    }
}

// ───────────────────────── online (sharded) router ─────────────────────────

/// Shared state of the online router.
struct OnlineShared {
    family: Arc<dyn HashFamily>,
    index: Arc<ShardedIndex>,
    feats: Arc<FeatureStore>,
    stats: Arc<RouterStats>,
    budget: QueryBudget,
}

/// Per-query merge rendezvous: shard jobs deposit partial hits; the last
/// one to finish merges, records stats and resolves the caller's ticket.
struct MergeState {
    id: u64,
    lookup: u64,
    masks: Arc<Vec<u64>>,
    w: Vec<f32>,
    exclude: Option<Arc<HashSet<usize>>>,
    submitted: Instant,
    remaining: AtomicUsize,
    partials: Mutex<Vec<QueryHit>>,
    reply: Mutex<Option<Sender<QueryResponse>>>,
}

struct OnlineJob {
    shard: usize,
    state: Arc<MergeState>,
}

/// Fan-out router over a dynamic [`ShardedIndex`]: one job per shard per
/// query, merged on completion. Writers mutate the index concurrently
/// through their own `Arc<ShardedIndex>` handle — workers only ever touch
/// epoch snapshots, so serving and churn never block each other.
pub struct OnlineRouter {
    tx: SyncSender<OnlineJob>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
    stats: Arc<RouterStats>,
    shared: Arc<OnlineShared>,
}

impl OnlineRouter {
    /// Spawn `workers` probe threads. `queue_cap` bounds the *shard job*
    /// queue (it is raised to at least one full query's fan-out so a
    /// single submit can never deadlock on its own jobs).
    pub fn new(
        family: Arc<dyn HashFamily>,
        index: Arc<ShardedIndex>,
        feats: Arc<FeatureStore>,
        workers: usize,
        queue_cap: usize,
        budget: QueryBudget,
    ) -> Self {
        let stats = Arc::new(RouterStats::default());
        let shared = Arc::new(OnlineShared {
            family,
            index: index.clone(),
            feats,
            stats: stats.clone(),
            budget,
        });
        let (tx, rx) = sync_channel::<OnlineJob>(queue_cap.max(index.shard_count()));
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers.max(1))
            .map(|_| {
                let rx = rx.clone();
                let sh = shared.clone();
                std::thread::spawn(move || online_worker_loop(rx, sh))
            })
            .collect();
        OnlineRouter { tx, workers: handles, next_id: AtomicU64::new(0), stats, shared }
    }

    pub fn stats(&self) -> &RouterStats {
        &self.stats
    }

    pub fn index(&self) -> &Arc<ShardedIndex> {
        &self.shared.index
    }

    /// The hash family queries are encoded with.
    pub fn family(&self) -> &Arc<dyn HashFamily> {
        &self.shared.family
    }

    /// The serving feature store (margins are ranked against its rows).
    pub fn feats(&self) -> &Arc<FeatureStore> {
        &self.shared.feats
    }

    /// The per-shard probe budget every query runs under.
    pub fn budget(&self) -> QueryBudget {
        self.shared.budget
    }

    /// Submit one query: the leader encodes the hyperplane, materializes
    /// the query-adapted probe plan once, and enqueues one job per shard.
    pub fn submit(&self, req: QueryRequest) -> Pending {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let lookup = self.shared.family.encode_query(&req.w);
        let scores = self.shared.family.query_bit_scores(&req.w);
        let masks = Arc::new(
            self.shared.index.plan_masks(scores.as_deref(), self.shared.budget.probes),
        );
        let n_shards = self.shared.index.shard_count();
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let state = Arc::new(MergeState {
            id,
            lookup,
            masks,
            w: req.w,
            exclude: req.exclude,
            submitted: Instant::now(),
            remaining: AtomicUsize::new(n_shards),
            partials: Mutex::new(Vec::with_capacity(n_shards)),
            reply: Mutex::new(Some(reply_tx)),
        });
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        for shard in 0..n_shards {
            self.tx
                .send(OnlineJob { shard, state: state.clone() })
                .expect("online router workers are gone");
        }
        Pending { id, rx: reply_rx }
    }

    /// Submit a batch and wait for all responses, in submission order.
    pub fn submit_batch(&self, reqs: Vec<QueryRequest>) -> Vec<QueryResponse> {
        let pendings: Vec<Pending> = reqs.into_iter().map(|r| self.submit(r)).collect();
        pendings.into_iter().map(|p| p.wait()).collect()
    }

    /// Synchronous data-parallel batch: answer `reqs` on the caller
    /// thread, reusing `pool` for the per-shard fan-out of each query
    /// ([`ShardedIndex::query_code_pool`]) instead of the job queue. Hits
    /// come back in request order with the same per-shard budget
    /// semantics as [`Self::submit`]; counters are updated, latency
    /// percentiles are not.
    pub fn query_batch_pooled(
        &self,
        reqs: &[QueryRequest],
        pool: &crate::par::Pool,
    ) -> Vec<QueryHit> {
        self.query_batch_pooled_traced(reqs, pool).0
    }

    /// [`Self::query_batch_pooled`] plus the batch's per-stage
    /// wall-clock (encode / probe planning / shard scan / merge), summed
    /// over requests. The untraced entry point delegates here, so traced
    /// and untraced hits are bit-identical by construction.
    pub fn query_batch_pooled_traced(
        &self,
        reqs: &[QueryRequest],
        pool: &crate::par::Pool,
    ) -> (Vec<QueryHit>, crate::obs::StageTimes) {
        let sh = &self.shared;
        let run_one =
            |req: &QueryRequest, fan: &crate::par::Pool| -> (QueryHit, crate::obs::StageTimes) {
                let mut st = crate::obs::StageTimes::default();
                let t0 = Instant::now();
                let lookup = sh.family.encode_query(&req.w);
                let scores = sh.family.query_bit_scores(&req.w);
                st.encode = t0.elapsed();
                let hit = match &req.exclude {
                    Some(ex) => sh.index.query_code_pool_timed(
                        lookup,
                        scores.as_deref(),
                        &req.w,
                        &sh.feats,
                        sh.budget,
                        |i| !ex.contains(&i),
                        fan,
                        &mut st,
                    ),
                    None => sh.index.query_code_pool_timed(
                        lookup,
                        scores.as_deref(),
                        &req.w,
                        &sh.feats,
                        sh.budget,
                        |_| true,
                        fan,
                        &mut st,
                    ),
                };
                (hit, st)
            };
        // Many queries: parallelize across requests (each request's shard
        // fan-out then runs inline on its worker) — shard count must not
        // cap batch parallelism. A single query instead spends the
        // workers on its per-shard fan-out. Hits are identical either
        // way: shard partials always merge in shard order.
        let results: Vec<(QueryHit, crate::obs::StageTimes)> = if reqs.len() == 1 {
            vec![run_one(&reqs[0], pool)]
        } else {
            pool.map(reqs.len(), crate::table::QUERY_CHUNK, |range| {
                range
                    .map(|qi| run_one(&reqs[qi], &crate::par::Pool::serial()))
                    .collect::<Vec<_>>()
            })
            .into_iter()
            .flatten()
            .collect()
        };
        let mut times = crate::obs::StageTimes::default();
        let mut hits = Vec::with_capacity(results.len());
        for (h, st) in results {
            times.add(&st);
            hits.push(h);
        }
        let scanned: usize = hits.iter().map(|h| h.scanned).sum();
        let empty = hits.iter().filter(|h| !h.nonempty).count();
        self.stats.submitted.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        self.stats.completed.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        self.stats.empty_lookups.fetch_add(empty as u64, Ordering::Relaxed);
        self.stats.candidates_scanned.fetch_add(scanned as u64, Ordering::Relaxed);
        (hits, times)
    }

    /// Drain the queue and join the workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for h in self.workers {
            let _ = h.join();
        }
    }
}

fn online_worker_loop(rx: Arc<Mutex<Receiver<OnlineJob>>>, sh: Arc<OnlineShared>) {
    // per-thread probe scratch, reused across every shard job this worker
    // serves (see table::QueryScratch — answers are unaffected)
    let mut scratch = crate::table::QueryScratch::new();
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(j) => j,
                Err(_) => return,
            }
        };
        let st = &job.state;
        let view = sh.index.shards()[job.shard].view();
        let hit = match &st.exclude {
            Some(ex) => view.query_with(
                &st.masks,
                st.lookup,
                &st.w,
                &sh.feats,
                sh.budget.top,
                |i| !ex.contains(&i),
                &mut scratch,
            ),
            None => view.query_with(
                &st.masks,
                st.lookup,
                &st.w,
                &sh.feats,
                sh.budget.top,
                |_| true,
                &mut scratch,
            ),
        };
        st.partials.lock().unwrap().push(hit);
        if st.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // last shard done: merge, record, reply
            let parts = std::mem::take(&mut *st.partials.lock().unwrap());
            let hit = merge_hits(&parts);
            sh.stats.completed.fetch_add(1, Ordering::Relaxed);
            if !hit.nonempty {
                sh.stats.empty_lookups.fetch_add(1, Ordering::Relaxed);
            }
            sh.stats
                .candidates_scanned
                .fetch_add(hit.scanned as u64, Ordering::Relaxed);
            let latency = st.submitted.elapsed();
            sh.stats.latencies.lock().unwrap().record_duration(latency);
            if let Some(tx) = st.reply.lock().unwrap().take() {
                let _ = tx.send(QueryResponse { id: st.id, hit, latency });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::test_blobs;
    use crate::hash::BhHash;
    use crate::rng::Rng;
    use crate::testing::unit_vec;

    fn setup(n: usize) -> (Arc<BhHash>, Arc<HyperplaneIndex>, Arc<FeatureStore>, Rng) {
        let mut rng = Rng::seed_from_u64(11);
        let ds = test_blobs(n, 16, 3, &mut rng);
        let fam = Arc::new(BhHash::sample(16, 10, &mut rng));
        let idx = Arc::new(HyperplaneIndex::build(fam.as_ref(), ds.features(), 4));
        (fam, idx, Arc::new(ds.features().clone()), rng)
    }

    #[test]
    fn router_answers_all_queries() {
        let (fam, idx, feats, mut rng) = setup(500);
        let router = Router::new(fam.clone(), idx.clone(), feats.clone(), 2, 16);
        let reqs: Vec<QueryRequest> = (0..40)
            .map(|_| QueryRequest { w: unit_vec(&mut rng, 16), exclude: None })
            .collect();
        let resps = router.submit_batch(reqs);
        assert_eq!(resps.len(), 40);
        // in submission order
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
        assert_eq!(router.stats().completed.load(Ordering::Relaxed), 40);
        assert_eq!(router.stats().submitted.load(Ordering::Relaxed), 40);
        router.shutdown();
    }

    #[test]
    fn router_matches_direct_index_query() {
        let (fam, idx, feats, mut rng) = setup(300);
        let router = Router::new(fam.clone(), idx.clone(), feats.clone(), 3, 8);
        for _ in 0..10 {
            let w = unit_vec(&mut rng, 16);
            let direct = idx.query_filtered(fam.as_ref(), &w, &feats, |_| true);
            let resp = router.submit(QueryRequest { w, exclude: None }).wait();
            assert_eq!(resp.hit.best.map(|(i, _)| i), direct.best.map(|(i, _)| i));
            assert_eq!(resp.hit.scanned, direct.scanned);
        }
        router.shutdown();
    }

    #[test]
    fn exclusion_set_respected() {
        let (fam, idx, feats, mut rng) = setup(200);
        let router = Router::new(fam.clone(), idx.clone(), feats.clone(), 2, 8);
        let w = unit_vec(&mut rng, 16);
        let unfiltered = router
            .submit(QueryRequest { w: w.clone(), exclude: None })
            .wait();
        if let Some((best, _)) = unfiltered.hit.best {
            let mut ex = HashSet::new();
            ex.insert(best);
            let filtered = router
                .submit(QueryRequest { w, exclude: Some(Arc::new(ex)) })
                .wait();
            assert_ne!(filtered.hit.best.map(|(i, _)| i), Some(best));
        }
        router.shutdown();
    }

    #[test]
    fn stats_accumulate_latencies() {
        let (fam, idx, feats, mut rng) = setup(100);
        let router = Router::new(fam, idx, feats, 1, 4);
        for _ in 0..20 {
            router
                .submit(QueryRequest { w: unit_vec(&mut rng, 16), exclude: None })
                .wait();
        }
        assert!(router.stats().latency_mean() > 0.0);
        assert!(router.stats().latency_p95() >= router.stats().latency_p50());
        router.shutdown();
    }

    fn setup_online(
        n: usize,
        shards: usize,
    ) -> (Arc<BhHash>, Arc<ShardedIndex>, Arc<FeatureStore>, Rng) {
        let mut rng = Rng::seed_from_u64(31);
        let ds = test_blobs(n, 16, 3, &mut rng);
        let fam = Arc::new(BhHash::sample(16, 10, &mut rng));
        let codes = fam.encode_all(ds.features());
        let idx = Arc::new(ShardedIndex::from_codes(&codes, 4, shards));
        (fam, idx, Arc::new(ds.features().clone()), rng)
    }

    #[test]
    fn online_router_matches_inline_query() {
        let (fam, idx, feats, mut rng) = setup_online(600, 4);
        let budget = QueryBudget::unlimited();
        let router = OnlineRouter::new(fam.clone(), idx.clone(), feats.clone(), 3, 16, budget);
        for _ in 0..12 {
            let w = unit_vec(&mut rng, 16);
            let direct = idx.query(fam.as_ref(), &w, &feats, budget, |_| true);
            let resp = router.submit(QueryRequest { w, exclude: None }).wait();
            assert_eq!(resp.hit.best.map(|(i, _)| i), direct.best.map(|(i, _)| i));
            assert_eq!(resp.hit.scanned, direct.scanned);
            assert_eq!(resp.hit.nonempty, direct.nonempty);
        }
        assert_eq!(router.stats().completed.load(Ordering::Relaxed), 12);
        router.shutdown();
    }

    #[test]
    fn online_router_serves_during_churn() {
        let (fam, idx, feats, mut rng) = setup_online(800, 4);
        let router = Arc::new(OnlineRouter::new(
            fam.clone(),
            idx.clone(),
            feats.clone(),
            2,
            8,
            QueryBudget::unlimited(),
        ));
        // writer thread: remove even ids, re-insert some
        let widx = idx.clone();
        let wfam = fam.clone();
        let wfeats = feats.clone();
        let writer = std::thread::spawn(move || {
            for id in (0..800u32).step_by(2) {
                widx.remove(id);
                if id % 8 == 0 {
                    widx.insert_point(wfam.as_ref(), id, wfeats.row(id as usize));
                }
            }
            widx.compact();
        });
        let mut answered = 0usize;
        for _ in 0..30 {
            let w = unit_vec(&mut rng, 16);
            let resp = router.submit(QueryRequest { w, exclude: None }).wait();
            if let Some((i, m)) = resp.hit.best {
                assert!(i < 800);
                assert!(m.is_finite() && m >= 0.0);
                answered += 1;
            }
        }
        writer.join().unwrap();
        // after churn settles: removed-and-not-reinserted ids never surface
        for _ in 0..20 {
            let w = unit_vec(&mut rng, 16);
            let resp = router.submit(QueryRequest { w, exclude: None }).wait();
            if let Some((i, _)) = resp.hit.best {
                let i = i as u32;
                assert!(i % 2 == 1 || i % 8 == 0, "removed id {i} returned");
            }
        }
        assert!(answered > 0, "full-ball queries on 800 points should hit");
    }

    #[test]
    fn online_router_respects_exclusions_and_order() {
        let (fam, idx, feats, mut rng) = setup_online(300, 2);
        let router =
            OnlineRouter::new(fam, idx, feats, 2, 4, QueryBudget::unlimited());
        let w = unit_vec(&mut rng, 16);
        let first = router.submit(QueryRequest { w: w.clone(), exclude: None }).wait();
        let reqs: Vec<QueryRequest> = (0..8)
            .map(|_| QueryRequest { w: w.clone(), exclude: None })
            .collect();
        let resps = router.submit_batch(reqs);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.id, 1 + i as u64, "submission order preserved");
        }
        if let Some((best, _)) = first.hit.best {
            let mut ex = HashSet::new();
            ex.insert(best);
            let filtered = router
                .submit(QueryRequest { w, exclude: Some(Arc::new(ex)) })
                .wait();
            assert_ne!(filtered.hit.best.map(|(i, _)| i), Some(best));
        }
        router.shutdown();
    }

    #[test]
    fn pooled_batch_matches_queued_path() {
        let (fam, idx, feats, mut rng) = setup(400);
        let router = Router::new(fam.clone(), idx.clone(), feats.clone(), 2, 16);
        let reqs: Vec<QueryRequest> = (0..12)
            .map(|_| QueryRequest { w: unit_vec(&mut rng, 16), exclude: None })
            .collect();
        let queued = router.submit_batch(reqs.clone());
        let pooled = router.query_batch_pooled(&reqs, &crate::par::Pool::new(4));
        assert_eq!(pooled.len(), queued.len());
        for (p, q) in pooled.iter().zip(queued.iter()) {
            assert_eq!(p.best, q.hit.best);
            assert_eq!(p.scanned, q.hit.scanned);
        }
        router.shutdown();
    }

    #[test]
    fn online_pooled_batch_matches_queued_path() {
        let (fam, idx, feats, mut rng) = setup_online(500, 3);
        let router = OnlineRouter::new(
            fam,
            idx,
            feats,
            2,
            8,
            QueryBudget::new(128, 64),
        );
        let reqs: Vec<QueryRequest> = (0..10)
            .map(|_| QueryRequest { w: unit_vec(&mut rng, 16), exclude: None })
            .collect();
        let queued = router.submit_batch(reqs.clone());
        for workers in [1, 4] {
            let pooled = router.query_batch_pooled(&reqs, &crate::par::Pool::new(workers));
            for (p, q) in pooled.iter().zip(queued.iter()) {
                assert_eq!(p.best, q.hit.best, "workers={workers}");
                assert_eq!(p.scanned, q.hit.scanned);
                assert_eq!(p.nonempty, q.hit.nonempty);
            }
        }
        router.shutdown();
    }

    #[test]
    fn traced_batch_is_bit_identical_and_reports_stages() {
        // online path: all four stages populate
        let (fam, idx, feats, mut rng) = setup_online(500, 3);
        let router = OnlineRouter::new(fam, idx, feats, 2, 8, QueryBudget::new(128, 64));
        let reqs: Vec<QueryRequest> = (0..10)
            .map(|_| QueryRequest { w: unit_vec(&mut rng, 16), exclude: None })
            .collect();
        let pool = crate::par::Pool::new(2);
        let plain = router.query_batch_pooled(&reqs, &pool);
        let (traced, times) = router.query_batch_pooled_traced(&reqs, &pool);
        assert_eq!(plain.len(), traced.len());
        for (p, t) in plain.iter().zip(traced.iter()) {
            assert_eq!(p.best.map(|(i, m)| (i, m.to_bits())), t.best.map(|(i, m)| (i, m.to_bits())));
            assert_eq!(p.scanned, t.scanned);
            assert_eq!(p.probed, t.probed);
            assert_eq!(p.nonempty, t.nonempty);
        }
        assert!(times.encode > Duration::ZERO, "encode stage timed");
        assert!(times.scan > Duration::ZERO, "scan stage timed");
        router.shutdown();
        // static path: encode + scan populate, probe/merge stay zero
        let (fam, idx, feats, mut rng) = setup(300);
        let router = Router::new(fam, idx, feats, 2, 8);
        let reqs: Vec<QueryRequest> = (0..6)
            .map(|_| QueryRequest { w: unit_vec(&mut rng, 16), exclude: None })
            .collect();
        let plain = router.query_batch_pooled(&reqs, &pool);
        let (traced, times) = router.query_batch_pooled_traced(&reqs, &pool);
        for (p, t) in plain.iter().zip(traced.iter()) {
            assert_eq!(p.best.map(|(i, m)| (i, m.to_bits())), t.best.map(|(i, m)| (i, m.to_bits())));
            assert_eq!(p.scanned, t.scanned);
        }
        assert!(times.scan > Duration::ZERO);
        assert_eq!(times.probe, Duration::ZERO, "static path has no separate probe stage");
        router.shutdown();
    }

    #[test]
    fn concurrent_submitters_under_backpressure() {
        let (fam, idx, feats, _rng) = setup(400);
        let router = Arc::new(Router::new(fam, idx, feats, 2, 2)); // tiny queue
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let r = router.clone();
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::seed_from_u64(100 + t);
                let mut got = 0usize;
                for _ in 0..25 {
                    let w = unit_vec(&mut rng, 16);
                    let resp = r.submit(QueryRequest { w, exclude: None }).wait();
                    assert!(resp.latency >= Duration::ZERO);
                    got += 1;
                }
                got
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(total, 100);
        assert_eq!(router.stats().completed.load(Ordering::Relaxed), 100);
    }
}
