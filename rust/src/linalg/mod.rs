//! Dense linear algebra on `f32` slices — the native (non-PJRT) hot path.
//!
//! The vendored registry has no BLAS binding, so the inner loops here are
//! written to auto-vectorize: fixed-stride unrolled accumulators, no bounds
//! checks in the hot loops (slices pre-chunked), f32 storage with f64
//! accumulation only where numerically required.

/// Row-major dense matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec shape mismatch");
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// C = self * other, naive tiled row-major GEMM.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul inner dim");
        let mut c = Mat::zeros(self.rows, other.cols);
        // ikj ordering: stream other's rows, accumulate into c's row.
        for i in 0..self.rows {
            let a_row = self.row(i);
            let c_row = &mut c.data[i * other.cols..(i + 1) * other.cols];
            for (k, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = other.row(k);
                axpy(a, b_row, c_row);
            }
        }
        c
    }

    /// self^T as a new matrix.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// y = self · x  (GEMV).
    pub fn gemv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len(), "gemv dim");
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// ℓ2-normalize every row in place; zero rows are left untouched.
    pub fn l2_normalize_rows(&mut self) {
        let cols = self.cols;
        for i in 0..self.rows {
            let r = &mut self.data[i * cols..(i + 1) * cols];
            let n = nrm2(r);
            if n > 0.0 {
                let inv = 1.0 / n;
                for v in r.iter_mut() {
                    *v *= inv;
                }
            }
        }
    }
}

/// Dot product with 4-lane unrolled accumulation (auto-vectorizes well).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Row-block height of [`project_block`]: how many data points share one
/// pass over a block of projection rows. 8 rows × (up to 64 bits) of f32
/// outputs stay register/L1-resident while the projection block streams.
pub const GEMM_ROW_BLOCK: usize = 8;

/// Bit-block width of [`project_block`]: how many projection rows are
/// kept hot across a row block. 16 rows × 1024 dims × 4 B = 64 KB worst
/// case (news profile) — L2-resident; at the tiny/test profiles
/// (≤ 384 dims) the block fits in L1.
pub const GEMM_BIT_BLOCK: usize = 16;

/// Cache-blocked projection: `out[r * k + j] = dot(x.row(row0 + r), proj.row(j))`
/// for `r < nrows`, `j < proj.rows`, with `k = proj.rows`.
///
/// This is the GEMM `X[row0..row0+nrows] · projᵀ`, re-blocked so a
/// [`GEMM_BIT_BLOCK`]-row slab of `proj` is reused across
/// [`GEMM_ROW_BLOCK`] data rows before moving on — the projection matrix
/// is streamed once per row *block* instead of once per row. Every
/// output entry is produced by the **same** unrolled [`dot`] the scalar
/// `ProjectionPairs::project` path uses, in the same operand order, so
/// the blocked result is bit-identical to the per-point reference by
/// construction: blocking only reorders *independent* (row, bit)
/// entries, never the float accumulation inside one entry.
pub fn project_block(x: &Mat, row0: usize, nrows: usize, proj: &Mat, out: &mut [f32]) {
    let k = proj.rows;
    debug_assert_eq!(x.cols, proj.cols, "project_block dim");
    debug_assert!(out.len() >= nrows * k, "project_block out too small");
    for j0 in (0..k).step_by(GEMM_BIT_BLOCK) {
        let j1 = (j0 + GEMM_BIT_BLOCK).min(k);
        for r in 0..nrows {
            let xr = x.row(row0 + r);
            let orow = &mut out[r * k..r * k + k];
            for j in j0..j1 {
                orow[j] = dot(xr, proj.row(j));
            }
        }
    }
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// x *= alpha.
#[inline]
pub fn scal(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

/// ℓ2-normalize in place; returns the original norm.
pub fn normalize(x: &mut [f32]) -> f32 {
    let n = nrm2(x);
    if n > 0.0 {
        scal(1.0 / n, x);
    }
    n
}

/// Cosine of the angle between a and b (0 when either is zero).
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = nrm2(a);
    let nb = nrm2(b);
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        (dot(a, b) / (na * nb)).clamp(-1.0, 1.0)
    }
}

/// Point-to-hyperplane angle α_{x,w} = |θ − π/2| = asin(|cos θ|)  (eq. 1).
pub fn point_hyperplane_angle(x: &[f32], w: &[f32]) -> f32 {
    cosine(x, w).abs().clamp(0.0, 1.0).asin()
}

/// Paper's "distance" measure D(x, P_w) = α². (Theorem 1's metric.)
pub fn alpha_sq(x: &[f32], w: &[f32]) -> f32 {
    let a = point_hyperplane_angle(x, w);
    a * a
}

/// |wᵀx| / ‖w‖ — the true point-to-hyperplane margin used for re-ranking.
pub fn margin(x: &[f32], w: &[f32], w_norm: f32) -> f32 {
    if w_norm == 0.0 {
        0.0
    } else {
        dot(x, w).abs() / w_norm
    }
}

/// Margin for a dense-or-sparse feature reference.
pub fn margin_feat(x: crate::data::FeatRef<'_>, w: &[f32], w_norm: f32) -> f32 {
    if w_norm == 0.0 {
        0.0
    } else {
        x.dot(w).abs() / w_norm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..131).map(|i| (i as f32) * 0.3 - 10.0).collect();
        let b: Vec<f32> = (0..131).map(|i| (i as f32) * -0.7 + 3.0).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!(close(dot(&a, &b), naive, 1e-5));
    }

    #[test]
    fn matmul_identity() {
        let mut eye = Mat::zeros(3, 3);
        for i in 0..3 {
            eye.set(i, i, 1.0);
        }
        let a = Mat::from_vec(3, 3, (0..9).map(|i| i as f32).collect());
        assert_eq!(a.matmul(&eye), a);
        assert_eq!(eye.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gemv_matches_matmul() {
        let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let x = vec![0.5, -1.5];
        let y = a.gemv(&x);
        assert_eq!(y, vec![1. * 0.5 - 2. * 1.5, 3. * 0.5 - 4. * 1.5, 5. * 0.5 - 6. * 1.5]);
    }

    #[test]
    fn normalize_unit_norm() {
        let mut v = vec![3.0, 4.0];
        let n = normalize(&mut v);
        assert!(close(n, 5.0, 1e-6));
        assert!(close(nrm2(&v), 1.0, 1e-6));
    }

    #[test]
    fn cosine_bounds_and_symmetry() {
        let a = vec![1.0, 0.0, 2.0];
        let b = vec![-1.0, 3.0, 0.5];
        let c1 = cosine(&a, &b);
        let c2 = cosine(&b, &a);
        assert!(close(c1, c2, 1e-6));
        assert!((-1.0..=1.0).contains(&c1));
    }

    #[test]
    fn angle_perpendicular_is_zero() {
        // x ⟂ w → θ = π/2 → α = 0: the most informative point.
        let w = vec![1.0, 0.0];
        let x = vec![0.0, 5.0];
        assert!(point_hyperplane_angle(&x, &w).abs() < 1e-6);
    }

    #[test]
    fn angle_parallel_is_half_pi() {
        let w = vec![1.0, 0.0];
        let x = vec![-2.0, 0.0];
        assert!(close(point_hyperplane_angle(&x, &w), std::f32::consts::FRAC_PI_2, 1e-5));
    }

    #[test]
    fn margin_scale_invariant_in_w() {
        let x = vec![1.0, 2.0, -0.5];
        let w = vec![0.3, -0.1, 0.8];
        let m1 = margin(&x, &w, nrm2(&w));
        let w2: Vec<f32> = w.iter().map(|v| v * 7.0).collect();
        let m2 = margin(&x, &w2, nrm2(&w2));
        assert!(close(m1, m2, 1e-5));
    }

    #[test]
    fn project_block_bit_identical_to_dot_loop() {
        // ragged shapes: rows not a multiple of the row block, bits not a
        // multiple of the bit block, dims not a multiple of dot's unroll
        let mut rng = crate::rng::Rng::seed_from_u64(9);
        for (n, d, k) in [(1, 5, 1), (7, 33, 3), (20, 19, 21), (9, 64, 17)] {
            let x = Mat::from_vec(n, d, (0..n * d).map(|_| rng.gauss_f32()).collect());
            let p = Mat::from_vec(k, d, (0..k * d).map(|_| rng.gauss_f32()).collect());
            for row0 in [0, n / 2] {
                let nrows = (n - row0).min(GEMM_ROW_BLOCK);
                let mut out = vec![0.0f32; nrows * k];
                project_block(&x, row0, nrows, &p, &mut out);
                for r in 0..nrows {
                    for j in 0..k {
                        let want = dot(x.row(row0 + r), p.row(j));
                        assert_eq!(
                            out[r * k + j].to_bits(),
                            want.to_bits(),
                            "n={n} d={d} k={k} row0={row0} r={r} j={j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn l2_normalize_rows_handles_zero_rows() {
        let mut m = Mat::from_vec(2, 2, vec![0., 0., 3., 4.]);
        m.l2_normalize_rows();
        assert_eq!(&m.data[0..2], &[0., 0.]);
        assert!(close(m.get(1, 0), 0.6, 1e-6));
        assert!(close(m.get(1, 1), 0.8, 1e-6));
    }
}
