//! Deterministic pseudo-random number generation.
//!
//! The vendored crate registry has no `rand`, so the crate ships its own
//! generator: **xoshiro256++** seeded through **SplitMix64** (the reference
//! seeding procedure from Blackman & Vigna). Everything downstream — data
//! synthesis, random projections, the AL loop, property tests — draws from
//! this module so that every experiment is reproducible from a single `u64`
//! seed recorded in the results files.

/// SplitMix64: used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Not cryptographic; fast, high-quality for simulation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller deviate
    gauss_spare: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Seed the full 256-bit state from a single u64 via SplitMix64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (used to fan out per-worker RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from_u64(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid u == 0 (log of zero).
        let mut u = self.f64();
        while u <= f64::MIN_POSITIVE {
            u = self.f64();
        }
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    #[inline]
    pub fn gauss_f32(&mut self) -> f32 {
        self.gauss() as f32
    }

    /// Vector of iid standard normals.
    pub fn gauss_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gauss_f32()).collect()
    }

    /// Exponential(1).
    pub fn exponential(&mut self) -> f64 {
        let mut u = self.f64();
        while u <= f64::MIN_POSITIVE {
            u = self.f64();
        }
        -u.ln()
    }

    /// Log-normal with parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.gauss()).exp()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        // For small k relative to n, do rejection from a set; otherwise shuffle.
        if k * 8 < n {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.below(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

/// Zipf(s) sampler over ranks 1..=n via precomputed CDF (used by the
/// synthetic text corpus generator: word frequencies are Zipfian).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Sample a 0-based rank.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_reseeds() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Rng::seed_from_u64(3);
        let n = 7usize;
        let mut counts = vec![0usize; n];
        let trials = 70_000;
        for _ in 0..trials {
            counts[r.below(n)] += 1;
        }
        let expect = trials as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(), "{counts:?}");
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::seed_from_u64(11);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let z = r.gauss();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.01, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var {m2}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from_u64(9);
        for &(n, k) in &[(100usize, 5usize), (10, 10), (1000, 999), (50, 0)] {
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), k);
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn zipf_monotone_frequencies() {
        let z = Zipf::new(1000, 1.1);
        let mut r = Rng::seed_from_u64(13);
        let mut counts = vec![0usize; 1000];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        // rank 0 should dominate rank 100 which dominates rank 900
        assert!(counts[0] > counts[100]);
        assert!(counts[0] > 20 * counts[900].max(1) / 2);
    }

    #[test]
    fn fork_streams_independent() {
        let mut a = Rng::seed_from_u64(21);
        let mut c1 = a.fork();
        let mut c2 = a.fork();
        let same = (0..100).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
