//! Result emission: CSV files under `results/`, paper-style console tables,
//! and ASCII line plots for the figure benchmarks so curve *shapes* can be
//! eyeballed straight from `cargo bench` output.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Directory results are written to (created on demand).
pub fn results_dir() -> PathBuf {
    let dir = std::env::var("CHH_RESULTS_DIR").unwrap_or_else(|_| "results".to_string());
    PathBuf::from(dir)
}

/// Write a CSV file: header row + data rows. Returns the path written.
pub fn write_csv(name: &str, header: &[&str], rows: &[Vec<String>]) -> anyhow::Result<PathBuf> {
    let dir = results_dir();
    fs::create_dir_all(&dir)?;
    let path = dir.join(name);
    let mut f = fs::File::create(&path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(path)
}

/// A named series of (x, y) points.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str) -> Self {
        Series { name: name.to_string(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }
}

/// Render multiple series as an ASCII plot (x binned to `width` columns).
pub fn ascii_plot(title: &str, series: &[Series], width: usize, height: usize) -> String {
    let mut out = format!("── {title} ──\n");
    let pts: Vec<&(f64, f64)> = series.iter().flat_map(|s| s.points.iter()).collect();
    if pts.is_empty() {
        out.push_str("(no data)\n");
        return out;
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &&(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-300 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-300 {
        y1 = y0 + 1.0;
    }
    let marks = ['*', 'o', '+', 'x', '#', '@', '%', '&'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let m = marks[si % marks.len()];
        for &(x, y) in &s.points {
            let cx = (((x - x0) / (x1 - x0)) * (width as f64 - 1.0)).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (height as f64 - 1.0)).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = m;
        }
    }
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{y1:>10.4} ")
        } else if i == height - 1 {
            format!("{y0:>10.4} ")
        } else {
            " ".repeat(11)
        };
        out.push_str(&label);
        out.push('|');
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&" ".repeat(11));
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("{:>12}{:>width$.4}\n", format!("{x0:.4}"), x1, width = width - 1));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", marks[si % marks.len()], s.name));
    }
    out
}

/// Print a fixed-width table with a title.
pub fn print_rows(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n── {title} ──");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8) + 2))
            .collect::<String>()
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Save experiment record as JSON under results/.
pub fn write_json(name: &str, value: &crate::jsonio::Json) -> anyhow::Result<PathBuf> {
    let dir = results_dir();
    fs::create_dir_all(&dir)?;
    let path = dir.join(name);
    fs::write(&path, value.to_string_pretty())?;
    Ok(path)
}

/// Read back a results JSON (used by report aggregation and tests).
pub fn read_json(path: &Path) -> anyhow::Result<crate::jsonio::Json> {
    let text = fs::read_to_string(path)?;
    Ok(crate::jsonio::Json::parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_plot_contains_series_marks() {
        let mut s1 = Series::new("a");
        let mut s2 = Series::new("b");
        for i in 0..20 {
            s1.push(i as f64, (i as f64).sin());
            s2.push(i as f64, (i as f64).cos());
        }
        let plot = ascii_plot("t", &[s1, s2], 40, 10);
        assert!(plot.contains('*'));
        assert!(plot.contains('o'));
        assert!(plot.contains("a\n") && plot.contains("b\n"));
    }

    #[test]
    fn ascii_plot_empty() {
        let plot = ascii_plot("t", &[], 10, 5);
        assert!(plot.contains("no data"));
    }

    #[test]
    fn csv_and_json_roundtrip() {
        let tmp = std::env::temp_dir().join(format!("chh_report_test_{}", std::process::id()));
        std::env::set_var("CHH_RESULTS_DIR", &tmp);
        let p = write_csv("t.csv", &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        let j = crate::jsonio::obj(vec![("x", crate::jsonio::Json::from(3usize))]);
        let p = write_json("t.json", &j).unwrap();
        let back = read_json(&p).unwrap();
        assert_eq!(back.get("x").unwrap().as_usize(), Some(3));
        std::env::remove_var("CHH_RESULTS_DIR");
        let _ = std::fs::remove_dir_all(&tmp);
    }
}
