//! # chh — Compact Hyperplane Hashing with Bilinear Functions
//!
//! A three-layer (Rust coordinator + JAX graph + Pallas kernel) reproduction
//! of *Compact Hyperplane Hashing with Bilinear Functions* (Liu, Wang, Mu,
//! Kumar, Chang — ICML 2012).
//!
//! The library answers **point-to-hyperplane** nearest-neighbor queries:
//! given a hyperplane `P_w` (e.g. an SVM decision boundary with normal `w`)
//! and a database of points, return the points with the smallest
//! point-to-hyperplane angle `α_{x,w} = |θ_{x,w} − π/2|`. That primitive is
//! what makes margin-based SVM active learning scale past ~10⁵ samples.
//!
//! ## Layers
//!
//! * **L3 (this crate)** — the coordinator: hash tables, Hamming-ball
//!   lookup, the LBH trainer driver, the SVM active-learning engine, a
//!   hyperplane-query router/batcher, the online serving subsystem
//!   (sharded dynamic index + probability-ordered multi-probe, see
//!   [`online`]), a data-parallel batch engine for the offline hot paths
//!   (encode / batch query / train / eval, see [`par`] and
//!   `docs/PARALLEL.md`), an HTTP serving front-end with dynamic
//!   micro-batching (see [`server`] and `docs/SERVING.md`), a durability
//!   subsystem for the online index — write-ahead log, background
//!   snapshots, crash recovery (see [`wal`] and `docs/DURABILITY.md`) —
//!   replicated serving via WAL shipping — primary/replica read scaling
//!   with bit-identical replica answers (see [`replicate`] and
//!   `docs/REPLICATION.md`) — cluster serving via partitioned primaries
//!   behind a stateless scatter-gather router tier (see [`cluster`] and
//!   `docs/CLUSTER.md`) — and the PJRT runtime that executes
//!   AOT-compiled XLA artifacts.
//! * **L2 (python/compile/model.py)** — JAX graphs for batch encoding,
//!   LBH Nesterov training steps, margin scans and Hamming ranking, lowered
//!   once to HLO text by `make artifacts`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the bilinear
//!   form `(X·U) ⊙ (X·V)`, the LBH gradient, and ±1-matvec Hamming ranking.
//!
//! Python never runs on the query path: the `chh` binary is self-contained
//! once `artifacts/` is built.
//!
//! ## Hash families
//!
//! | family | form | collision prob (point vs hyperplane) |
//! |---|---|---|
//! | AH-Hash | `[sgn(uᵀz), sgn(±vᵀz)]` | `1/4 − α²/π²` |
//! | EH-Hash | `sgn(±Uᵀvec(zzᵀ))` | `acos(sin²α)/π` |
//! | BH-Hash | `sgn(uᵀz·zᵀv)` | `1/2 − 2α²/π²` (Lemma 1) |
//! | LBH-Hash | learned `(u_j, v_j)` | — (trained, §4 of the paper) |
//!
//! ## Quickstart
//!
//! ```no_run
//! use chh::prelude::*;
//!
//! let mut rng = Rng::seed_from_u64(7);
//! let data = chh::data::tiny1m_like(&TinyConfig { n: 20_000, ..TinyConfig::default() }, &mut rng);
//! let family = chh::hash::BhHash::sample(data.dim(), 20, &mut rng);
//! let index = chh::table::HyperplaneIndex::build(&family, data.features(), 4);
//! let w = vec![0.1f32; data.dim()];
//! let hit = index.query(&family, &w, data.features());
//! println!("{hit:?}");
//! ```
//!
//! ## Online serving
//!
//! The static table answers queries over a fixed database; the [`online`]
//! subsystem serves a *changing* one — dynamic insert/remove, per-shard
//! epoch snapshots and a best-first probe planner with a per-query budget
//! (`docs/ONLINE.md` has the architecture notes):
//!
//! ```no_run
//! use chh::prelude::*;
//!
//! let mut rng = Rng::seed_from_u64(7);
//! let data = chh::data::tiny1m_like(&TinyConfig { n: 20_000, ..TinyConfig::default() }, &mut rng);
//! let family = chh::hash::BhHash::sample(data.dim(), 20, &mut rng);
//! let index = ShardedIndex::new(20, 4, 8);
//! for i in 0..data.len() {
//!     index.insert_point(&family, i as u32, data.features().row(i));
//! }
//! let w = vec![0.1f32; data.dim()];
//! let hit = index.query(&family, &w, data.features(), QueryBudget::new(512, 64), |_| true);
//! index.remove(hit.best.map(|(i, _)| i as u32).unwrap_or(0));
//! ```

pub mod active;
pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod hash;
pub mod jsonio;
pub mod lbh;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod online;
pub mod par;
pub mod persist;
pub mod replicate;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod sparse;
pub mod svm;
pub mod table;
pub mod testing;
pub mod wal;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::active::{AlConfig, AlEngine, AlResult, Strategy};
    pub use crate::cluster::{ClusterRouter, PartitionMap};
    pub use crate::data::{newsgroups_like, tiny1m_like, Dataset, FeatureStore, NewsConfig, TinyConfig};
    pub use crate::hash::{AhHash, BhHash, EhHash, HashFamily, LbhHash};
    pub use crate::lbh::{LbhTrainer, LbhTrainConfig};
    pub use crate::online::{ProbePlanner, QueryBudget, ShardedIndex};
    pub use crate::par::Pool;
    pub use crate::replicate::{ReplicaConfig, ReplicaIndex};
    pub use crate::rng::Rng;
    pub use crate::svm::{LinearSvm, SvmConfig};
    pub use crate::table::{HyperplaneIndex, QueryHit};
    pub use crate::wal::{DurableIndex, FsyncPolicy, WalConfig};
}
