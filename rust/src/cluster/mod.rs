//! Cluster serving: partitioned primaries behind a scatter-gather
//! router tier.
//!
//! PR 5's replication scales *reads* of one primary; this subsystem
//! scales *writes and memory* by partitioning the id space across N
//! independent primaries (each with its own WAL and replica set) and
//! putting a stateless router tier in front:
//!
//! ```text
//!            clients (JSON over HTTP)
//!                      │
//!             ┌────────┴────────┐
//!             │   chh route     │   × M stateless routers
//!             │  (scatter/merge)│
//!             └───┬───────┬─────┘
//!        binary wire       binary wire
//!             │                 │
//!   ┌─────────┴───┐     ┌───────┴─────┐
//!   │ primary 0   │     │ primary 1   │   ids [0,k)  /  [k,n)
//!   │  WAL + idx  │     │  WAL + idx  │
//!   │  replicas…  │     │  replicas…  │
//!   └─────────────┘     └─────────────┘
//! ```
//!
//! * [`map`] — the versioned partition-map format: contiguous id
//!   ranges → endpoints, overlap/gap validation, a `family_check`
//!   fingerprint so mismatched codes are refused at load, persisted via
//!   `persist::atomic_write`.
//! * [`router`] — [`ClusterRouter`]: keep-alive pooled fan-out of
//!   `/query`/`/query_topk` with the exact `OnlineRouter` merge
//!   semantics, id-routed mutations with 421-following map refresh,
//!   per-partition primary→replica failover, and degraded
//!   partial-answer reporting.
//! * [`split`] — [`split_partition`]: the growth story; carve one
//!   WAL-backed range into two fresh primaries and emit the
//!   next-version map.
//!
//! Served by `Stack::Cluster` in `server/` (`chh route`); documented in
//! `docs/CLUSTER.md`.

pub mod map;
pub mod router;
pub mod split;

pub use map::{Partition, PartitionMap};
pub use router::{ClusterAnswer, ClusterConfig, ClusterError, ClusterMeta, ClusterRouter};
pub use split::{split_partition, SplitReport, SplitTarget};
