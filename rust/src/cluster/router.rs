//! The stateless scatter-gather router.
//!
//! A `ClusterRouter` owns a validated [`PartitionMap`] plus keep-alive
//! connection pools to every partition endpoint, and lifts the
//! in-process `OnlineRouter` fan-out/merge over HTTP:
//!
//! * `/query` is broadcast to **every** partition over the binary wire
//!   protocol and the per-partition [`QueryHit`]s are folded with
//!   [`crate::online::merge_hits`] — the same margin-then-id semantics
//!   as a single node, so a 1-partition cluster is bit-identical to
//!   querying that node directly (pinned by `tests/cluster.rs`).
//! * `/query_topk` concatenates the per-partition short lists and
//!   re-sorts with `ShardedIndex::query_topk`'s exact tie-break
//!   (margin ascending, then id ascending), truncating to `t`.
//! * `/insert` / `/remove` are routed to the **one** primary owning the
//!   id range; a 421 reply (the map is stale, the target is now a
//!   replica) triggers a map reload plus a single redirect-following
//!   retry, reusing the replication tier's redirect body.
//!
//! Reads fail over primary → replicas in map order. A partition with no
//! reachable target does not fail the query: the survivors' merge is
//! returned as a **degraded partial answer** (`"partial": true` upstream
//! and `chh_router_partial_answers_total`), never a silent short list.
//! Only when *no* partition answers does the router return 503.
//!
//! The router is deliberately stateless: it holds no index, no WAL, and
//! can be restarted or scaled horizontally at will. All durable state
//! lives in the partitions; the only configuration is the map.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::QueryRequest;
use crate::jsonio::{obj, Json};
use crate::obs::{decode_stages, PartitionSpan};
use crate::online::merge_hits;
use crate::server::binproto;
use crate::server::http::HttpClient;
use crate::table::QueryHit;

use super::map::PartitionMap;

/// Idle keep-alive connections retained per endpoint.
const POOL_CAP: usize = 8;

/// An error with an upstream-facing HTTP status.
#[derive(Debug)]
pub struct ClusterError {
    pub status: u16,
    pub msg: String,
}

impl ClusterError {
    fn new(status: u16, msg: impl Into<String>) -> Self {
        ClusterError { status, msg: msg.into() }
    }
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}", self.status, self.msg)
    }
}

/// What the router learned about the cluster at startup: every
/// partition must agree on all four fields.
#[derive(Clone, Debug)]
pub struct ClusterMeta {
    pub dim: usize,
    pub bits: usize,
    pub family: String,
    pub family_check: u32,
}

/// Monotone counters for the router's /metrics and /stats.
#[derive(Default)]
pub struct ClusterStats {
    /// scatter-gather reads issued (each fans out to every partition)
    pub fanout_reads: AtomicU64,
    /// reads answered with at least one partition missing
    pub partial_answers: AtomicU64,
    /// reads answered by a replica because the primary was unreachable
    pub failovers: AtomicU64,
    /// mutations that hit a 421 and were retried at the advertised primary
    pub stale_map_retries: AtomicU64,
    /// successful partition-map installs (POST /map or disk reload)
    pub map_reloads: AtomicU64,
    /// downstream requests that errored (transport or non-2xx)
    pub downstream_errors: AtomicU64,
    /// mutations routed by id range
    pub mutations_routed: AtomicU64,
}

impl ClusterStats {
    fn inc(c: &AtomicU64) {
        c.fetch_add(1, Ordering::Relaxed);
    }
    fn get(c: &AtomicU64) -> u64 {
        c.load(Ordering::Relaxed)
    }
}

/// Dial/read-timeout knobs for downstream connections.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// per-dial connect timeout
    pub connect_timeout: Duration,
    /// socket read/write timeout on established connections
    pub io_timeout: Duration,
    /// how long [`ClusterRouter::connect`] retries each partition's
    /// startup probe before giving up
    pub probe_wait: Duration,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            connect_timeout: Duration::from_secs(1),
            io_timeout: Duration::from_secs(5),
            probe_wait: Duration::from_secs(10),
        }
    }
}

/// One installed map generation plus its per-partition health flags.
/// Swapped wholesale (behind an `Arc`) on every map install, so a
/// scatter-gather in flight keeps a consistent view.
struct MapState {
    map: PartitionMap,
    healthy: Vec<AtomicBool>,
}

impl MapState {
    fn new(map: PartitionMap) -> Self {
        let healthy = (0..map.partitions.len()).map(|_| AtomicBool::new(true)).collect();
        MapState { map, healthy }
    }
}

/// The answer to one scatter-gather read, plus the router-side timing
/// the server folds into `chh_partition_seconds` and the cross-tier
/// slow-log line.
pub struct ClusterAnswer<T> {
    pub value: T,
    /// indices of partitions that did not answer (empty ⇒ complete)
    pub failed: Vec<usize>,
    /// one span per partition that answered: router-side wait plus the
    /// per-stage breakdown the partition echoed in `x-chh-stages`
    pub spans: Vec<PartitionSpan>,
    /// wall time of the whole scatter + gather
    pub fanout: Duration,
    /// wall time of the router-side merge of partition answers
    pub merge: Duration,
}

impl<T> ClusterAnswer<T> {
    pub fn partial(&self) -> bool {
        !self.failed.is_empty()
    }
}

/// One partition's raw fan-out result: the body plus the router-side
/// wait and the echoed stage header.
struct PartObs {
    body: Vec<u8>,
    wait: Duration,
    stages: Option<String>,
}

pub struct ClusterRouter {
    state: Mutex<Arc<MapState>>,
    /// where the map came from on disk (None when installed in memory);
    /// consulted by [`reload_map`](Self::reload_map) after a 421
    map_path: Option<PathBuf>,
    meta: ClusterMeta,
    cfg: ClusterConfig,
    /// idle keep-alive connections, keyed by endpoint address
    pool: Mutex<HashMap<String, Vec<HttpClient>>>,
    stats: ClusterStats,
}

impl ClusterRouter {
    /// Validate `map`, probe every partition's `/stats`, and require a
    /// consistent online index family across the cluster. Refuses to
    /// start if any partition serves a different `family_check` than
    /// the map declares — mismatched codes are a config error, not
    /// something to discover query by query.
    pub fn connect(
        map: PartitionMap,
        map_path: Option<PathBuf>,
        cfg: ClusterConfig,
    ) -> anyhow::Result<ClusterRouter> {
        map.validate().map_err(|e| anyhow::anyhow!("partition map: {e}"))?;
        let mut meta: Option<ClusterMeta> = None;
        for (i, p) in map.partitions.iter().enumerate() {
            let m = Self::probe_partition(p, &cfg)
                .map_err(|e| anyhow::anyhow!("partition {i} ({}): {e}", p.primary))?;
            if m.family_check != map.family_check() {
                anyhow::bail!(
                    "partition {i} ({}): serves family_check {} but the map declares {} — \
                     refusing to merge answers across hash families",
                    p.primary,
                    m.family_check,
                    map.family_check()
                );
            }
            match &meta {
                None => meta = Some(m),
                Some(first) => {
                    if m.dim != first.dim
                        || m.bits != first.bits
                        || m.family != first.family
                        || m.family_check != first.family_check
                    {
                        anyhow::bail!(
                            "partition {i} ({}): dim/bits/family {}/{}/{} disagrees with \
                             partition 0's {}/{}/{}",
                            p.primary,
                            m.dim,
                            m.bits,
                            m.family,
                            first.dim,
                            first.bits,
                            first.family
                        );
                    }
                }
            }
        }
        let meta = meta.expect("validated map has at least one partition");
        Ok(Self::with_meta(map, map_path, cfg, meta))
    }

    /// Build a router around an already-known cluster shape, without
    /// probing anything. Used by tests and by `connect` itself.
    pub fn with_meta(
        map: PartitionMap,
        map_path: Option<PathBuf>,
        cfg: ClusterConfig,
        meta: ClusterMeta,
    ) -> ClusterRouter {
        ClusterRouter {
            state: Mutex::new(Arc::new(MapState::new(map))),
            map_path,
            meta,
            cfg,
            pool: Mutex::new(HashMap::new()),
            stats: ClusterStats::default(),
        }
    }

    /// Probe one partition (primary first, then replicas) for its
    /// /stats identity fields.
    fn probe_partition(
        p: &super::map::Partition,
        cfg: &ClusterConfig,
    ) -> Result<ClusterMeta, String> {
        let mut last = String::new();
        for (ti, addr) in std::iter::once(&p.primary).chain(p.replicas.iter()).enumerate() {
            let dialed = if ti == 0 {
                HttpClient::connect_retry(addr, cfg.probe_wait)
            } else {
                HttpClient::connect_with_timeout(addr, cfg.connect_timeout)
            };
            let mut client = match dialed {
                Ok(c) => c,
                Err(e) => {
                    last = format!("{addr}: connect: {e}");
                    continue;
                }
            };
            let _ = client.set_timeout(cfg.io_timeout);
            let resp = match client.get("/stats") {
                Ok(r) if r.status == 200 => r,
                Ok(r) => {
                    last = format!("{addr}: /stats returned {}", r.status);
                    continue;
                }
                Err(e) => {
                    last = format!("{addr}: /stats: {e}");
                    continue;
                }
            };
            return Self::parse_stats_meta(&resp.body).map_err(|e| format!("{addr}: {e}"));
        }
        Err(last)
    }

    fn parse_stats_meta(body: &[u8]) -> Result<ClusterMeta, String> {
        let v = Json::parse_bytes(body).map_err(|e| format!("bad /stats json: {e}"))?;
        let mode = v.get("mode").and_then(|x| x.as_str()).unwrap_or("?");
        if mode != "online" {
            return Err(format!(
                "mode is '{mode}' but partitions must serve a mutable online index"
            ));
        }
        let need = |k: &str| {
            v.get(k).and_then(|x| x.as_usize()).ok_or_else(|| format!("/stats missing '{k}'"))
        };
        Ok(ClusterMeta {
            dim: need("dim")?,
            bits: need("bits")?,
            family: v
                .get("family")
                .and_then(|x| x.as_str())
                .ok_or("/stats missing 'family'")?
                .to_string(),
            family_check: need("family_check")? as u32,
        })
    }

    // ---- connection pool -------------------------------------------------

    fn pool_take(&self, addr: &str) -> Option<HttpClient> {
        self.pool.lock().unwrap().get_mut(addr).and_then(Vec::pop)
    }

    fn pool_put(&self, addr: &str, client: HttpClient) {
        let mut pool = self.pool.lock().unwrap();
        let slot = pool.entry(addr.to_string()).or_default();
        if slot.len() < POOL_CAP {
            slot.push(client);
        }
    }

    fn dial(&self, addr: &str) -> std::io::Result<HttpClient> {
        let client = HttpClient::connect_with_timeout(addr, self.cfg.connect_timeout)?;
        let _ = client.set_timeout(self.cfg.io_timeout);
        Ok(client)
    }

    /// POST one binary frame to `addr`, reusing a pooled keep-alive
    /// connection when one exists. A pooled connection that fails is
    /// assumed stale (the peer may have restarted) and the request is
    /// retried exactly once on a fresh dial.
    fn post_bin(
        &self,
        addr: &str,
        path: &str,
        frame: &[u8],
        rid: Option<&str>,
    ) -> Result<(u16, Vec<u8>, Option<String>), String> {
        let pooled = self.pool_take(addr);
        let had_pooled = pooled.is_some();
        let mut client = match pooled {
            Some(c) => c,
            None => self.dial(addr).map_err(|e| format!("connect {addr}: {e}"))?,
        };
        let resp = match client.post_binary_with_id(path, frame, rid) {
            Ok(r) => r,
            Err(_) if had_pooled => {
                // stale pooled socket — one fresh retry
                let mut fresh = self.dial(addr).map_err(|e| format!("connect {addr}: {e}"))?;
                let r = fresh
                    .post_binary_with_id(path, frame, rid)
                    .map_err(|e| format!("{addr} {path}: {e}"))?;
                client = fresh;
                r
            }
            Err(e) => return Err(format!("{addr} {path}: {e}")),
        };
        if resp.keep_alive {
            self.pool_put(addr, client);
        }
        Ok((resp.status, resp.body, resp.stages))
    }

    // ---- reads -----------------------------------------------------------

    /// Read from partition `pi`: primary first, then replicas in map
    /// order. Any 200 wins; everything else (connect failure, timeout,
    /// 503 shed, 5xx) moves on to the next target. Updates the health
    /// flag and the failover counter. The returned wait covers the
    /// whole target loop — failover attempts are part of what the
    /// caller waited for.
    fn partition_read(
        &self,
        st: &MapState,
        pi: usize,
        path: &str,
        frame: &[u8],
        rid: Option<&str>,
    ) -> Result<PartObs, String> {
        let p = &st.map.partitions[pi];
        let start = Instant::now();
        let mut last = String::from("no targets");
        for (ti, addr) in std::iter::once(&p.primary).chain(p.replicas.iter()).enumerate() {
            match self.post_bin(addr, path, frame, rid) {
                Ok((200, body, stages)) => {
                    st.healthy[pi].store(true, Ordering::Relaxed);
                    if ti > 0 {
                        ClusterStats::inc(&self.stats.failovers);
                    }
                    return Ok(PartObs { body, wait: start.elapsed(), stages });
                }
                Ok((status, _, _)) => {
                    ClusterStats::inc(&self.stats.downstream_errors);
                    last = format!("{addr} {path}: status {status}");
                }
                Err(e) => {
                    ClusterStats::inc(&self.stats.downstream_errors);
                    last = e;
                }
            }
        }
        st.healthy[pi].store(false, Ordering::Relaxed);
        Err(last)
    }

    /// Scatter `path`+`frame` to every partition concurrently and
    /// return the per-partition observations (`Err` slots are
    /// partitions with no reachable target).
    fn fanout(
        &self,
        st: &MapState,
        path: &str,
        frame: &[u8],
        rid: Option<&str>,
    ) -> Vec<Result<PartObs, String>> {
        let n = st.map.partitions.len();
        if n == 1 {
            return vec![self.partition_read(st, 0, path, frame, rid)];
        }
        let mut out: Vec<Result<PartObs, String>> = Vec::with_capacity(n);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n)
                .map(|pi| scope.spawn(move || self.partition_read(st, pi, path, frame, rid)))
                .collect();
            for h in handles {
                out.push(h.join().expect("partition fan-out thread panicked"));
            }
        });
        out
    }

    /// Fold one partition's observation into the span list.
    fn span_of(pi: usize, o: &PartObs) -> PartitionSpan {
        PartitionSpan {
            partition: pi,
            wait: o.wait,
            stages: o.stages.as_deref().map(decode_stages).unwrap_or_default(),
        }
    }

    fn snapshot(&self) -> Arc<MapState> {
        Arc::clone(&self.state.lock().unwrap())
    }

    /// Scatter-gather `/query`: merge per-partition best hits with the
    /// exact `OnlineRouter` margin-then-id semantics.
    pub fn query(
        &self,
        req: &QueryRequest,
        rid: Option<&str>,
    ) -> Result<ClusterAnswer<QueryHit>, ClusterError> {
        let st = self.snapshot();
        ClusterStats::inc(&self.stats.fanout_reads);
        let frame = binproto::encode_query(&req.w, req.exclude.as_deref());
        let fan_start = Instant::now();
        let obs = self.fanout(&st, "/query", &frame, rid);
        let fanout = fan_start.elapsed();
        let merge_start = Instant::now();
        let mut hits: Vec<QueryHit> = Vec::new();
        let mut failed: Vec<usize> = Vec::new();
        let mut spans: Vec<PartitionSpan> = Vec::new();
        for (pi, r) in obs.into_iter().enumerate() {
            match r {
                Ok(o) => match binproto::decode_hit(&o.body) {
                    Ok(h) => {
                        spans.push(Self::span_of(pi, &o));
                        hits.push(h);
                    }
                    Err(e) => {
                        return Err(ClusterError::new(
                            502,
                            format!("partition {pi}: undecodable hit frame: {}", e.msg),
                        ))
                    }
                },
                Err(_) => failed.push(pi),
            }
        }
        if hits.is_empty() {
            return Err(ClusterError::new(503, "no partition answered the query"));
        }
        if !failed.is_empty() {
            ClusterStats::inc(&self.stats.partial_answers);
        }
        let value = merge_hits(&hits);
        Ok(ClusterAnswer { value, failed, spans, fanout, merge: merge_start.elapsed() })
    }

    /// Scatter-gather `/query_topk`: concatenate the per-partition
    /// short lists, re-sort (margin asc, id asc — `ShardedIndex`'s
    /// tie-break), truncate to `t`.
    pub fn query_topk(
        &self,
        req: &QueryRequest,
        t: usize,
        rid: Option<&str>,
    ) -> Result<ClusterAnswer<Vec<(usize, f32)>>, ClusterError> {
        let st = self.snapshot();
        ClusterStats::inc(&self.stats.fanout_reads);
        let frame = binproto::encode_topk(&req.w, t, req.exclude.as_deref());
        let fan_start = Instant::now();
        let obs = self.fanout(&st, "/query_topk", &frame, rid);
        let fanout = fan_start.elapsed();
        let merge_start = Instant::now();
        let mut scored: Vec<(usize, f32)> = Vec::new();
        let mut failed: Vec<usize> = Vec::new();
        let mut spans: Vec<PartitionSpan> = Vec::new();
        let mut answered = 0usize;
        for (pi, r) in obs.into_iter().enumerate() {
            match r {
                Ok(o) => match binproto::decode_topk_hits(&o.body) {
                    Ok(hits) => {
                        spans.push(Self::span_of(pi, &o));
                        answered += 1;
                        scored.extend(hits);
                    }
                    Err(e) => {
                        return Err(ClusterError::new(
                            502,
                            format!("partition {pi}: undecodable topk frame: {}", e.msg),
                        ))
                    }
                },
                Err(_) => failed.push(pi),
            }
        }
        if answered == 0 {
            return Err(ClusterError::new(503, "no partition answered the query"));
        }
        if !failed.is_empty() {
            ClusterStats::inc(&self.stats.partial_answers);
        }
        scored.sort_by(|a, b| {
            a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
        });
        scored.truncate(t);
        Ok(ClusterAnswer { value: scored, failed, spans, fanout, merge: merge_start.elapsed() })
    }

    // ---- mutations -------------------------------------------------------

    /// Route one insert/remove to the primary owning `id`. Follows a
    /// single 421 redirect (stale map: the target demoted itself to a
    /// replica and advertises its current primary), reloading the map
    /// from disk along the way so later mutations go straight to the
    /// right place.
    pub fn mutate(
        &self,
        insert: bool,
        id: u32,
        rid: Option<&str>,
    ) -> Result<(bool, u64), ClusterError> {
        let st = self.snapshot();
        let pi = st.map.partition_for(id).ok_or_else(|| {
            ClusterError::new(
                400,
                format!("id {id} is outside the partitioned id space 0..{}", st.map.id_space()),
            )
        })?;
        ClusterStats::inc(&self.stats.mutations_routed);
        let (tag, path) = if insert {
            (binproto::TAG_INSERT, "/insert")
        } else {
            (binproto::TAG_REMOVE, "/remove")
        };
        let frame = binproto::encode_id(tag, id);
        let primary = st.map.partitions[pi].primary.clone();
        let (status, body, _) = self.post_bin(&primary, path, &frame, rid).map_err(|e| {
            ClusterStats::inc(&self.stats.downstream_errors);
            ClusterError::new(503, format!("partition {pi} primary unreachable: {e}"))
        })?;
        let (status, body) = if status == 421 {
            // The map is stale: the target is a replica now and tells
            // us where its primary lives. Refresh and retry once.
            ClusterStats::inc(&self.stats.stale_map_retries);
            self.reload_map();
            let next = Json::parse_bytes(&body)
                .ok()
                .and_then(|v| v.get("primary").and_then(|p| p.as_str()).map(str::to_string))
                .ok_or_else(|| {
                    ClusterError::new(502, format!("partition {pi}: 421 without a primary address"))
                })?;
            let (s, b, _) = self.post_bin(&next, path, &frame, rid).map_err(|e| {
                ClusterStats::inc(&self.stats.downstream_errors);
                ClusterError::new(503, format!("redirected primary {next} unreachable: {e}"))
            })?;
            (s, b)
        } else {
            (status, body)
        };
        if status != 200 {
            ClusterStats::inc(&self.stats.downstream_errors);
            let msg = String::from_utf8_lossy(&body).into_owned();
            return Err(ClusterError::new(status, msg));
        }
        let (applied, _id, live) = binproto::decode_ack(&body)
            .map_err(|e| ClusterError::new(502, format!("undecodable ack: {}", e.msg)))?;
        Ok((applied, live))
    }

    // ---- map lifecycle ---------------------------------------------------

    /// Atomically flip to a newer map. The new map must validate, carry
    /// the cluster's family fingerprint, and strictly increase the
    /// version — a replayed or concurrent older map is refused with 409
    /// so routers converge on the newest config regardless of delivery
    /// order. Health flags reset to healthy; the next read re-probes.
    pub fn install_map(&self, new: PartitionMap) -> Result<u64, ClusterError> {
        new.validate().map_err(|e| ClusterError::new(400, e))?;
        if new.family_check() != self.meta.family_check {
            return Err(ClusterError::new(
                409,
                format!(
                    "map family_check {} does not match this cluster's {}",
                    new.family_check(),
                    self.meta.family_check
                ),
            ));
        }
        let mut state = self.state.lock().unwrap();
        if new.version <= state.map.version {
            return Err(ClusterError::new(
                409,
                format!(
                    "map version must increase: installed v{}, offered v{}",
                    state.map.version, new.version
                ),
            ));
        }
        let v = new.version;
        *state = Arc::new(MapState::new(new));
        ClusterStats::inc(&self.stats.map_reloads);
        Ok(v)
    }

    /// Best-effort reload from `map_path`; returns true when a newer
    /// map was installed.
    pub fn reload_map(&self) -> bool {
        let Some(path) = &self.map_path else { return false };
        match PartitionMap::load(path) {
            Ok(m) => self.install_map(m).is_ok(),
            Err(_) => false,
        }
    }

    // ---- introspection ---------------------------------------------------

    pub fn dim(&self) -> usize {
        self.meta.dim
    }

    pub fn meta(&self) -> &ClusterMeta {
        &self.meta
    }

    pub fn stats(&self) -> &ClusterStats {
        &self.stats
    }

    pub fn map_version(&self) -> u64 {
        self.snapshot().map.version
    }

    pub fn partition_count(&self) -> usize {
        self.snapshot().map.partitions.len()
    }

    pub fn id_space(&self) -> u32 {
        self.snapshot().map.id_space()
    }

    /// Health of partition `i` as a gauge value: 1 healthy, 0 down,
    /// -1 when the installed map no longer has a partition `i`.
    pub fn health_at(&self, i: usize) -> f64 {
        let st = self.snapshot();
        match st.healthy.get(i) {
            Some(h) => {
                if h.load(Ordering::Relaxed) {
                    1.0
                } else {
                    0.0
                }
            }
            None => -1.0,
        }
    }

    /// The currently installed map as JSON (`GET /map`).
    pub fn map_json(&self) -> Json {
        self.snapshot().map.to_json()
    }

    /// The `cluster` section of the router's `/stats` document.
    pub fn stats_json(&self) -> Json {
        let st = self.snapshot();
        let parts: Vec<Json> = st
            .map
            .partitions
            .iter()
            .enumerate()
            .map(|(i, p)| {
                obj(vec![
                    ("start", Json::from(p.start as usize)),
                    ("end", Json::from(p.end as usize)),
                    ("primary", Json::from(p.primary.as_str())),
                    ("replicas", Json::from(p.replicas.len())),
                    ("healthy", Json::from(st.healthy[i].load(Ordering::Relaxed))),
                ])
            })
            .collect();
        let s = &self.stats;
        obj(vec![
            ("map_version", Json::from(st.map.version as usize)),
            ("id_space", Json::from(st.map.id_space() as usize)),
            ("partitions", Json::Arr(parts)),
            ("fanout_reads", Json::from(ClusterStats::get(&s.fanout_reads) as usize)),
            ("partial_answers", Json::from(ClusterStats::get(&s.partial_answers) as usize)),
            ("failovers", Json::from(ClusterStats::get(&s.failovers) as usize)),
            ("stale_map_retries", Json::from(ClusterStats::get(&s.stale_map_retries) as usize)),
            ("map_reloads", Json::from(ClusterStats::get(&s.map_reloads) as usize)),
            ("downstream_errors", Json::from(ClusterStats::get(&s.downstream_errors) as usize)),
            ("mutations_routed", Json::from(ClusterStats::get(&s.mutations_routed) as usize)),
        ])
    }

    /// Counter snapshots for `register_metrics` closures.
    pub fn counters(&self) -> [(&'static str, u64); 7] {
        let s = &self.stats;
        [
            ("fanout", ClusterStats::get(&s.fanout_reads)),
            ("partial", ClusterStats::get(&s.partial_answers)),
            ("failover", ClusterStats::get(&s.failovers)),
            ("stale_map", ClusterStats::get(&s.stale_map_retries)),
            ("map_reload", ClusterStats::get(&s.map_reloads)),
            ("downstream_err", ClusterStats::get(&s.downstream_errors)),
            ("mutation", ClusterStats::get(&s.mutations_routed)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::map::Partition;

    fn two_part_map(version: u64, fc: u32) -> PartitionMap {
        PartitionMap {
            version,
            partitions: vec![
                Partition {
                    start: 0,
                    end: 100,
                    primary: "127.0.0.1:1".into(),
                    replicas: vec![],
                    family_check: fc,
                },
                Partition {
                    start: 100,
                    end: 200,
                    primary: "127.0.0.1:2".into(),
                    replicas: vec![],
                    family_check: fc,
                },
            ],
        }
    }

    fn router(fc: u32) -> ClusterRouter {
        ClusterRouter::with_meta(
            two_part_map(1, fc),
            None,
            ClusterConfig::default(),
            ClusterMeta { dim: 8, bits: 10, family: "bh".into(), family_check: fc },
        )
    }

    #[test]
    fn install_requires_strictly_increasing_version() {
        let r = router(7);
        assert_eq!(r.map_version(), 1);
        // same version: refused
        let err = r.install_map(two_part_map(1, 7)).unwrap_err();
        assert_eq!(err.status, 409);
        // older: refused
        let err = r.install_map(two_part_map(0, 7)).unwrap_err();
        assert_eq!(err.status, 409);
        // newer: installed
        assert_eq!(r.install_map(two_part_map(5, 7)).unwrap(), 5);
        assert_eq!(r.map_version(), 5);
        // and the bar moved
        let err = r.install_map(two_part_map(5, 7)).unwrap_err();
        assert_eq!(err.status, 409);
        assert_eq!(ClusterStats::get(&r.stats().map_reloads), 1);
    }

    #[test]
    fn install_refuses_foreign_family() {
        let r = router(7);
        let err = r.install_map(two_part_map(9, 8)).unwrap_err();
        assert_eq!(err.status, 409);
        assert!(err.msg.contains("family_check"), "{}", err.msg);
        assert_eq!(r.map_version(), 1);
    }

    #[test]
    fn install_refuses_invalid_maps() {
        let r = router(7);
        let mut gapped = two_part_map(9, 7);
        gapped.partitions[1].start = 150;
        assert_eq!(r.install_map(gapped).unwrap_err().status, 400);
    }

    #[test]
    fn health_defaults_and_out_of_range() {
        let r = router(7);
        assert_eq!(r.health_at(0), 1.0);
        assert_eq!(r.health_at(1), 1.0);
        assert_eq!(r.health_at(2), -1.0);
        assert_eq!(r.partition_count(), 2);
        assert_eq!(r.id_space(), 200);
    }

    #[test]
    fn mutate_rejects_ids_outside_the_map() {
        let r = router(7);
        let err = r.mutate(true, 200, None).unwrap_err();
        assert_eq!(err.status, 400);
        assert!(err.msg.contains("0..200"), "{}", err.msg);
    }
}
