//! Offline partition split: carve one WAL-backed id range into two.
//!
//! Growth path for a cluster: when one partition gets too big (memory,
//! write rate), split its id range at a midpoint and hand each half to
//! a fresh primary. The procedure is deliberately offline-per-partition
//! — the *rest* of the cluster keeps serving; only the partition being
//! split pauses writes:
//!
//! 1. stop the source primary (its WAL dir holds an exclusive lock, so
//!    [`split_partition`] physically cannot run against a live server —
//!    `DurableIndex::open` would fail to acquire the lock);
//! 2. recover the source index from its WAL (crash-consistent: the same
//!    recovery the server itself runs);
//! 3. route every live entry by `id < mid` into two fresh indexes that
//!    inherit the source's bits/radius/shards/budget;
//! 4. create two new WAL dirs, each seeded with a base snapshot of its
//!    half (generation 0 — the standard `DurableIndex::create` path, so
//!    the new primaries recover/replicate exactly like any other);
//! 5. emit the next-version partition map with the split range replaced
//!    by the two halves.
//!
//! The returned map is NOT installed anywhere: the operator (or
//! `chh partition-split`) saves it and POSTs it to each router's `/map`
//! endpoint, which flips atomically. Until the flip, routers keep
//! sending the old range to the stopped primary and fail over /
//! degrade per the normal read path — the documented runbook in
//! `docs/CLUSTER.md` sequences this so the write-unavailability window
//! is just the split itself.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context};

use crate::online::ShardedIndex;
use crate::wal::{is_wal_dir, DurableIndex, WalConfig};

use super::map::{Partition, PartitionMap};

/// What a split produced, for operator output and tests.
#[derive(Debug)]
pub struct SplitReport {
    /// live points that landed in `[start, mid)`
    pub left_points: usize,
    /// live points that landed in `[mid, end)`
    pub right_points: usize,
    /// the emitted map's version (source map version + 1)
    pub new_version: u64,
}

/// Addresses for the two new primaries taking over the halves.
#[derive(Clone, Debug)]
pub struct SplitTarget {
    pub addr: String,
    pub replicas: Vec<String>,
}

/// Split partition `pi` of `map` at id `mid`, materializing the two
/// halves as fresh WAL dirs (`left_dir`, `right_dir`) seeded from the
/// source partition's WAL (`src_dir`). Returns the next-version map and
/// a report. See the module doc for the full runbook.
pub fn split_partition(
    map: &PartitionMap,
    pi: usize,
    mid: u32,
    src_dir: &Path,
    left_dir: &Path,
    right_dir: &Path,
    left: &SplitTarget,
    right: &SplitTarget,
) -> anyhow::Result<(PartitionMap, SplitReport)> {
    map.validate().map_err(|e| anyhow::anyhow!("source map: {e}"))?;
    let Some(src_part) = map.partitions.get(pi) else {
        bail!("partition index {pi} out of range (map has {})", map.partitions.len());
    };
    if !(src_part.start < mid && mid < src_part.end) {
        bail!(
            "split point {mid} must fall strictly inside the partition's id range [{}, {})",
            src_part.start,
            src_part.end
        );
    }
    if !is_wal_dir(src_dir) {
        bail!("{} is not a WAL directory", src_dir.display());
    }
    for (name, dir) in [("left", left_dir), ("right", right_dir)] {
        if is_wal_dir(dir) {
            bail!(
                "{name} target {} already holds a WAL — refusing to overwrite",
                dir.display()
            );
        }
    }

    // Recover the source. This takes the WAL dir lock: if the source
    // primary is still running, this fails instead of forking history.
    let (src, report) = DurableIndex::open(&WalConfig::new(src_dir))
        .with_context(|| format!("recovering source partition from {}", src_dir.display()))?;
    let _ = report; // recovery details are the server's concern; we only need the index
    let idx = Arc::clone(src.index());

    // Two fresh indexes with the source's exact shape, so codes and
    // probe behavior carry over bit-for-bit.
    let lhs = ShardedIndex::new(idx.bits(), idx.radius(), idx.shard_count());
    let rhs = ShardedIndex::new(idx.bits(), idx.radius(), idx.shard_count());
    lhs.set_default_budget(idx.default_budget());
    rhs.set_default_budget(idx.default_budget());

    let (mut nl, mut nr) = (0usize, 0usize);
    for shard in idx.shards() {
        for (id, code) in shard.live_entries() {
            if !src_part.contains(id) {
                bail!(
                    "source WAL holds id {id}, outside the partition's declared range [{}, {}) — \
                     the map does not describe this WAL",
                    src_part.start,
                    src_part.end
                );
            }
            if id < mid {
                lhs.insert(id, code);
                nl += 1;
            } else {
                rhs.insert(id, code);
                nr += 1;
            }
        }
    }
    lhs.compact();
    rhs.compact();

    // Seed the new WAL dirs with base snapshots (generation 0), then
    // release everything cleanly.
    DurableIndex::create(Arc::new(lhs), &WalConfig::new(left_dir))
        .with_context(|| format!("creating left half at {}", left_dir.display()))?
        .close()?;
    DurableIndex::create(Arc::new(rhs), &WalConfig::new(right_dir))
        .with_context(|| format!("creating right half at {}", right_dir.display()))?
        .close()?;
    src.close()?;

    // Emit the next-version map: the split range becomes two entries.
    let mut partitions = Vec::with_capacity(map.partitions.len() + 1);
    for (i, p) in map.partitions.iter().enumerate() {
        if i == pi {
            partitions.push(Partition {
                start: p.start,
                end: mid,
                primary: left.addr.clone(),
                replicas: left.replicas.clone(),
                family_check: p.family_check,
            });
            partitions.push(Partition {
                start: mid,
                end: p.end,
                primary: right.addr.clone(),
                replicas: right.replicas.clone(),
                family_check: p.family_check,
            });
        } else {
            partitions.push(p.clone());
        }
    }
    let new_map = PartitionMap { version: map.version + 1, partitions };
    new_map
        .validate()
        .map_err(|e| anyhow::anyhow!("internal: emitted map failed validation: {e}"))?;
    Ok((
        new_map,
        SplitReport { left_points: nl, right_points: nr, new_version: new_map.version },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::BhHash;
    use crate::hash::HashFamily;
    use crate::rng::Rng;

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("chh_split_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn seeded_partition(dir: &Path, start: u32, end: u32) -> (BhHash, u32) {
        let mut rng = Rng::seed_from_u64(99);
        let fam = BhHash::sample(8, 10, &mut rng);
        let idx = Arc::new(ShardedIndex::new(10, 2, 3));
        for id in start..end {
            let w: Vec<f32> = rng.gauss_vec(8);
            idx.insert(id, fam.encode_query(&w));
        }
        idx.compact();
        let d = DurableIndex::create(Arc::clone(&idx), &WalConfig::new(dir)).expect("create wal");
        d.close().expect("close wal");
        let fc = crate::replicate::family_fingerprint(&fam, 8);
        (fam, fc)
    }

    fn one_part_map(end: u32, primary: &str, fc: u32) -> PartitionMap {
        PartitionMap {
            version: 3,
            partitions: vec![Partition {
                start: 0,
                end,
                primary: primary.into(),
                replicas: vec![],
                family_check: fc,
            }],
        }
    }

    #[test]
    fn split_partitions_every_point_and_bumps_the_version() {
        let src = tmpdir("src");
        let left = tmpdir("left");
        let right = tmpdir("right");
        let (_fam, fc) = seeded_partition(&src, 0, 120);
        let map = one_part_map(120, "127.0.0.1:9100", fc);
        let lt = SplitTarget { addr: "127.0.0.1:9101".into(), replicas: vec![] };
        let rt = SplitTarget {
            addr: "127.0.0.1:9102".into(),
            replicas: vec!["127.0.0.1:9103".into()],
        };
        let (new_map, rep) =
            split_partition(&map, 0, 50, &src, &left, &right, &lt, &rt).expect("split");
        assert_eq!(rep.left_points, 50);
        assert_eq!(rep.right_points, 70);
        assert_eq!(new_map.version, 4);
        assert_eq!(new_map.partitions.len(), 2);
        assert_eq!((new_map.partitions[0].start, new_map.partitions[0].end), (0, 50));
        assert_eq!((new_map.partitions[1].start, new_map.partitions[1].end), (50, 120));
        assert_eq!(new_map.partitions[0].primary, "127.0.0.1:9101");
        assert_eq!(new_map.partitions[1].replicas, vec!["127.0.0.1:9103".to_string()]);
        new_map.validate().expect("emitted map is valid");

        // Both halves recover as standard WAL dirs holding exactly
        // their id range, with the source's live entries preserved.
        let (dsrc, _) = DurableIndex::open(&WalConfig::new(&src)).expect("reopen source");
        let mut want: Vec<(u32, u64)> = dsrc
            .index()
            .shards()
            .iter()
            .flat_map(|s| s.live_entries())
            .collect();
        want.sort_unstable();
        drop(dsrc);
        let mut got: Vec<(u32, u64)> = Vec::new();
        for (dir, range) in [(&left, 0..50u32), (&right, 50..120u32)] {
            let (d, _) = DurableIndex::open(&WalConfig::new(dir)).expect("reopen half");
            let entries: Vec<(u32, u64)> =
                d.index().shards().iter().flat_map(|s| s.live_entries()).collect();
            for (id, _) in &entries {
                assert!(range.contains(id), "id {id} leaked outside {range:?}");
            }
            got.extend(entries);
            drop(d);
        }
        got.sort_unstable();
        assert_eq!(got, want, "split must preserve every live (id, code) pair");

        for d in [&src, &left, &right] {
            let _ = std::fs::remove_dir_all(d);
        }
    }

    #[test]
    fn split_rejects_bad_midpoints_and_occupied_targets() {
        let src = tmpdir("src2");
        let left = tmpdir("left2");
        let right = tmpdir("right2");
        let (_fam, fc) = seeded_partition(&src, 0, 40);
        let map = one_part_map(40, "127.0.0.1:9100", fc);
        let t = SplitTarget { addr: "127.0.0.1:9101".into(), replicas: vec![] };
        // mid on the boundary is refused
        for mid in [0, 40, 41] {
            assert!(split_partition(&map, 0, mid, &src, &left, &right, &t, &t).is_err());
        }
        // out-of-range partition index is refused
        assert!(split_partition(&map, 1, 20, &src, &left, &right, &t, &t).is_err());
        // a target that already holds a WAL is refused
        assert!(split_partition(&map, 0, 20, &src, &src, &right, &t, &t).is_err());
        let _ = std::fs::remove_dir_all(&src);
    }

    #[test]
    fn split_refuses_a_wal_outside_the_declared_range() {
        let src = tmpdir("src3");
        let left = tmpdir("left3");
        let right = tmpdir("right3");
        let (_fam, fc) = seeded_partition(&src, 0, 60);
        // map claims the partition only owns 0..30, but the WAL holds 0..60
        let map = one_part_map(30, "127.0.0.1:9100", fc);
        let t = SplitTarget { addr: "127.0.0.1:9101".into(), replicas: vec![] };
        let err = split_partition(&map, 0, 10, &src, &left, &right, &t, &t).unwrap_err();
        assert!(format!("{err:#}").contains("outside"), "{err:#}");
        // the failed split must not leave half-written targets behind
        assert!(!is_wal_dir(&left) && !is_wal_dir(&right));
        let _ = std::fs::remove_dir_all(&src);
    }
}
