//! The partition map: the cluster's single piece of shared
//! configuration.
//!
//! A map is a versioned list of **contiguous, non-overlapping id
//! ranges**, each owned by one partition primary (with an optional
//! replica set for read failover). Routers hold the whole map in
//! memory and consult it on every request; primaries never see it —
//! they just serve their id range like any single-node server.
//!
//! The serialized form is a small JSON document (hand-rolled via
//! [`crate::jsonio`], like every other wire format here):
//!
//! ```json
//! {
//!   "format": 1,
//!   "version": 3,
//!   "partitions": [
//!     {"start": 0,   "end": 500, "primary": "10.0.0.1:8080",
//!      "replicas": ["10.0.0.2:8080"], "family_check": 123456789},
//!     {"start": 500, "end": 1000, "primary": "10.0.0.3:8080",
//!      "replicas": [], "family_check": 123456789}
//!   ]
//! }
//! ```
//!
//! Invariants, enforced by [`PartitionMap::validate`] (parsing runs it,
//! so an invalid map cannot enter the process):
//!
//! * at least one partition; every range non-empty (`start < end`);
//! * ranges sorted, starting at id 0, and exactly contiguous —
//!   `partitions[i].end == partitions[i+1].start` — so overlaps and
//!   gaps are both structurally impossible;
//! * every partition declares the same `family_check` (the
//!   [`crate::replicate::family_fingerprint`] of the hash family its
//!   codes were produced with): one cluster, one family. Routers refuse
//!   to install a map whose fingerprint differs from the family they
//!   validated at startup, so mismatched codes are caught at load time
//!   rather than as silently-wrong merges.
//!
//! Maps are persisted with [`crate::persist::atomic_write`] (tmp +
//! fsync + rename), so a map file on disk is always a complete
//! document. `version` must increase on every change; routers reject
//! non-monotonic installs (see `ClusterRouter::install_map`).

use std::path::Path;

use crate::jsonio::{obj, Json};

/// Serialization format version; bumped only on layout changes.
pub const MAP_FORMAT: u64 = 1;

/// One contiguous id range and the endpoints serving it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    /// first id owned by this partition (inclusive)
    pub start: u32,
    /// one past the last id owned (exclusive)
    pub end: u32,
    /// the primary's `host:port` — mutations for this range go here
    pub primary: String,
    /// read replicas, in failover preference order
    pub replicas: Vec<String>,
    /// [`crate::replicate::family_fingerprint`] of the hash family the
    /// partition's codes were produced with
    pub family_check: u32,
}

impl Partition {
    pub fn contains(&self, id: u32) -> bool {
        self.start <= id && id < self.end
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("start", Json::from(self.start as usize)),
            ("end", Json::from(self.end as usize)),
            ("primary", Json::from(self.primary.as_str())),
            (
                "replicas",
                Json::Arr(self.replicas.iter().map(|r| Json::from(r.as_str())).collect()),
            ),
            ("family_check", Json::from(self.family_check as usize)),
        ])
    }

    fn from_json(v: &Json, i: usize) -> Result<Partition, String> {
        let field = |k: &str| {
            v.get(k)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| format!("partition {i}: missing/invalid '{k}'"))
        };
        let start = field("start")?;
        let end = field("end")?;
        let family_check = field("family_check")?;
        if start > u32::MAX as usize || end > u32::MAX as usize {
            return Err(format!("partition {i}: id range exceeds u32"));
        }
        if family_check > u32::MAX as usize {
            return Err(format!("partition {i}: family_check exceeds u32"));
        }
        let primary = v
            .get("primary")
            .and_then(|x| x.as_str())
            .ok_or_else(|| format!("partition {i}: missing/invalid 'primary'"))?
            .to_string();
        let replicas = match v.get("replicas") {
            None => Vec::new(),
            Some(r) => r
                .as_arr()
                .ok_or_else(|| format!("partition {i}: 'replicas' must be an array"))?
                .iter()
                .map(|x| {
                    x.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| format!("partition {i}: replica addr must be a string"))
                })
                .collect::<Result<Vec<_>, _>>()?,
        };
        Ok(Partition {
            start: start as u32,
            end: end as u32,
            primary,
            replicas,
            family_check: family_check as u32,
        })
    }
}

/// The versioned id-range → endpoint assignment for one cluster.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PartitionMap {
    /// monotone config version; routers refuse installs that do not
    /// strictly increase it
    pub version: u64,
    /// contiguous ranges covering `0..id_space()`, sorted by `start`
    pub partitions: Vec<Partition>,
}

impl PartitionMap {
    /// Check every structural invariant (see the module doc).
    pub fn validate(&self) -> Result<(), String> {
        if self.partitions.is_empty() {
            return Err("a partition map needs at least one partition".into());
        }
        let fc = self.partitions[0].family_check;
        let mut expect_start = 0u32;
        for (i, p) in self.partitions.iter().enumerate() {
            if p.start >= p.end {
                return Err(format!(
                    "partition {i}: empty or inverted range [{}, {})",
                    p.start, p.end
                ));
            }
            if p.start != expect_start {
                let what = if p.start > expect_start { "gap" } else { "overlap" };
                return Err(format!(
                    "partition {i}: {what} in id coverage — starts at {} but {} is expected \
                     (ranges must be sorted, contiguous, and begin at 0)",
                    p.start, expect_start
                ));
            }
            if p.primary.is_empty() {
                return Err(format!("partition {i}: empty primary address"));
            }
            if p.family_check != fc {
                return Err(format!(
                    "partition {i}: family_check {} != partition 0's {fc} — one cluster \
                     serves one hash family",
                    p.family_check
                ));
            }
            expect_start = p.end;
        }
        Ok(())
    }

    /// The cluster-wide family fingerprint (uniform across partitions —
    /// call only on a validated map).
    pub fn family_check(&self) -> u32 {
        self.partitions.first().map_or(0, |p| p.family_check)
    }

    /// One past the largest routable id.
    pub fn id_space(&self) -> u32 {
        self.partitions.last().map_or(0, |p| p.end)
    }

    /// Index of the partition owning `id` (None when `id` is outside
    /// the covered id space).
    pub fn partition_for(&self, id: u32) -> Option<usize> {
        // coverage is contiguous from 0, so the owner is the last
        // partition whose start is <= id
        let i = self.partitions.partition_point(|p| p.start <= id);
        if i == 0 {
            return None;
        }
        if self.partitions[i - 1].contains(id) {
            Some(i - 1)
        } else {
            None
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("format", Json::from(MAP_FORMAT as usize)),
            ("version", Json::from(self.version as usize)),
            (
                "partitions",
                Json::Arr(self.partitions.iter().map(Partition::to_json).collect()),
            ),
        ])
    }

    pub fn to_string_compact(&self) -> String {
        self.to_json().to_string_compact()
    }

    pub fn to_string_pretty(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Parse **and validate** one serialized map.
    pub fn parse(text: &str) -> Result<PartitionMap, String> {
        let v = Json::parse(text).map_err(|e| format!("partition map: {e}"))?;
        let format = v
            .get("format")
            .and_then(|x| x.as_usize())
            .ok_or("partition map: missing 'format'")?;
        if format as u64 != MAP_FORMAT {
            return Err(format!(
                "partition map: format {format} not supported (this build reads {MAP_FORMAT})"
            ));
        }
        let version = v
            .get("version")
            .and_then(|x| x.as_usize())
            .ok_or("partition map: missing 'version'")? as u64;
        let parts = v
            .get("partitions")
            .and_then(|x| x.as_arr())
            .ok_or("partition map: missing 'partitions' array")?;
        let partitions = parts
            .iter()
            .enumerate()
            .map(|(i, p)| Partition::from_json(p, i))
            .collect::<Result<Vec<_>, _>>()?;
        let map = PartitionMap { version, partitions };
        map.validate()?;
        Ok(map)
    }

    pub fn parse_bytes(bytes: &[u8]) -> Result<PartitionMap, String> {
        let text =
            std::str::from_utf8(bytes).map_err(|_| "partition map: not utf-8".to_string())?;
        Self::parse(text)
    }

    /// Persist atomically (tmp + fsync + rename): a reader never sees a
    /// torn map, and a crashed writer leaves the old version in place.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        self.validate().map_err(|e| anyhow::anyhow!("refusing to save: {e}"))?;
        let mut text = self.to_string_pretty();
        text.push('\n');
        crate::persist::atomic_write(path, text.as_bytes())
            .map_err(|e| anyhow::anyhow!("writing {}: {e:#}", path.display()))
    }

    pub fn load(path: &Path) -> anyhow::Result<PartitionMap> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::forall;

    fn addr(rng: &mut Rng) -> String {
        format!("10.0.{}.{}:{}", rng.below(256), rng.below(256), 1024 + rng.below(60000))
    }

    /// A random *valid* map: 1..=6 contiguous partitions from id 0.
    fn random_map(rng: &mut Rng) -> PartitionMap {
        let n = 1 + rng.below(6);
        let fc = rng.below(u32::MAX as usize) as u32;
        let version = rng.below(1_000_000) as u64;
        let mut partitions = Vec::with_capacity(n);
        let mut start = 0u32;
        for _ in 0..n {
            let end = start + 1 + rng.below(5000) as u32;
            let replicas = (0..rng.below(3)).map(|_| addr(rng)).collect();
            partitions.push(Partition {
                start,
                end,
                primary: addr(rng),
                replicas,
                family_check: fc,
            });
            start = end;
        }
        PartitionMap { version, partitions }
    }

    #[test]
    fn serialize_parse_roundtrip() {
        forall("map roundtrip", 200, |rng| {
            let m = random_map(rng);
            let compact = PartitionMap::parse(&m.to_string_compact())
                .map_err(|e| format!("compact reparse: {e}"))?;
            crate::prop_assert!(compact == m, "compact roundtrip changed the map");
            let pretty = PartitionMap::parse(&m.to_string_pretty())
                .map_err(|e| format!("pretty reparse: {e}"))?;
            crate::prop_assert!(pretty == m, "pretty roundtrip changed the map");
            Ok(())
        });
    }

    #[test]
    fn truncation_at_every_byte_is_a_clean_error() {
        let mut rng = Rng::seed_from_u64(41);
        let m = random_map(&mut rng);
        let s = m.to_string_compact();
        for cut in 0..s.len() {
            assert!(
                PartitionMap::parse(&s[..cut]).is_err(),
                "map cut at byte {cut} must fail to parse"
            );
        }
    }

    #[test]
    fn overlapping_and_gapped_ranges_are_rejected() {
        forall("map overlap/gap rejection", 100, |rng| {
            let mut m = random_map(rng);
            if m.partitions.len() < 2 {
                m.partitions.push(Partition {
                    start: m.id_space(),
                    end: m.id_space() + 10,
                    primary: addr(rng),
                    replicas: vec![],
                    family_check: m.family_check(),
                });
            }
            let i = 1 + rng.below(m.partitions.len() - 1);
            // shift one boundary: +delta opens a gap, -delta an overlap
            let mut gapped = m.clone();
            gapped.partitions[i].start += 1 + rng.below(50) as u32;
            crate::prop_assert!(
                PartitionMap::parse(&gapped.to_string_compact()).is_err(),
                "gap at partition {i} must be rejected"
            );
            let mut overlapped = m.clone();
            let width = overlapped.partitions[i - 1].end - overlapped.partitions[i - 1].start;
            overlapped.partitions[i].start -= 1 + rng.below(width as usize) as u32;
            crate::prop_assert!(
                PartitionMap::parse(&overlapped.to_string_compact()).is_err(),
                "overlap at partition {i} must be rejected"
            );
            Ok(())
        });
    }

    #[test]
    fn structural_invalids_are_rejected() {
        let mut rng = Rng::seed_from_u64(7);
        let m = random_map(&mut rng);
        // empty partition list
        assert!(PartitionMap::parse(r#"{"format":1,"version":1,"partitions":[]}"#).is_err());
        // wrong format version
        let wrong = m.to_string_compact().replacen("\"format\":1", "\"format\":99", 1);
        assert!(PartitionMap::parse(&wrong).is_err());
        // coverage must start at id 0
        let mut shifted = m.clone();
        for p in &mut shifted.partitions {
            p.start += 5;
            p.end += 5;
        }
        assert!(PartitionMap::parse(&shifted.to_string_compact()).is_err());
        // empty range
        let mut empty = m.clone();
        empty.partitions[0].end = empty.partitions[0].start;
        assert!(empty.validate().is_err());
        // mixed family fingerprints
        let mut mixed = m.clone();
        mixed.partitions[0].family_check ^= 1;
        if mixed.partitions.len() > 1 {
            assert!(PartitionMap::parse(&mixed.to_string_compact()).is_err());
        }
        // empty primary address
        let mut anon = m;
        anon.partitions[0].primary.clear();
        assert!(anon.validate().is_err());
    }

    #[test]
    fn partition_lookup_covers_the_id_space() {
        forall("map partition_for", 100, |rng| {
            let m = random_map(rng);
            for _ in 0..50 {
                let id = rng.below(m.id_space() as usize + 100) as u32;
                match m.partition_for(id) {
                    Some(i) => {
                        crate::prop_assert!(
                            m.partitions[i].contains(id),
                            "id {id} routed to partition {i} which does not own it"
                        );
                    }
                    None => {
                        crate::prop_assert!(
                            id >= m.id_space(),
                            "covered id {id} has no owning partition"
                        );
                    }
                }
            }
            crate::prop_assert!(
                m.partition_for(m.id_space()).is_none(),
                "id_space() itself must be unroutable"
            );
            Ok(())
        });
    }

    #[test]
    fn save_load_roundtrip_is_atomic_format() {
        let mut rng = Rng::seed_from_u64(13);
        let m = random_map(&mut rng);
        let path = std::env::temp_dir()
            .join(format!("chh_map_{}_{}.json", std::process::id(), m.version));
        m.save(&path).expect("save map");
        let back = PartitionMap::load(&path).expect("load map");
        assert_eq!(back, m);
        let _ = std::fs::remove_file(&path);
    }
}
