//! End-to-end driver: SVM active learning on the Tiny-1M-like image corpus
//! (paper §5, Fig. 4), with the PJRT-backed batch encoder on the
//! preprocessing path when `artifacts/` is present.
//!
//! Default is a 20k-point run; `--n 100k` or `--n 1m` scales up
//! (1M × 384 f32 ≈ 1.5 GB resident).
//!
//! Run: `cargo run --release --example active_learning_tiny [-- --n 100k]`

use std::sync::Arc;

use chh::active::{AlConfig, AlEngine, Strategy};
use chh::config::{DatasetProfile, ExperimentConfig};
use chh::data::{tiny1m_like, TinyConfig};
use chh::hash::{BhHash, HashFamily};
use chh::lbh::{LbhTrainConfig, LbhTrainer};
use chh::rng::Rng;
use chh::table::HyperplaneIndex;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut n = 20_000usize;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--n" && i + 1 < args.len() {
            let v = args[i + 1].to_lowercase();
            n = if let Some(p) = v.strip_suffix('k') {
                p.parse::<usize>().unwrap() * 1000
            } else if let Some(p) = v.strip_suffix('m') {
                p.parse::<usize>().unwrap() * 1_000_000
            } else {
                v.parse().unwrap()
            };
            i += 2;
        } else {
            i += 1;
        }
    }
    let mut cfg = ExperimentConfig::for_profile(DatasetProfile::Tiny);
    cfg.n = n;
    cfg.al_iters = if n > 50_000 { 300 } else { 100 };
    cfg.runs = 2;
    cfg.max_classes = Some(if n > 50_000 { 10 } else { 4 });

    let mut rng = Rng::seed_from_u64(cfg.seed);
    println!("tiny1m-like corpus: n={n} d=384 (k={} bits, radius {})", cfg.bits(), cfg.radius());
    let data = tiny1m_like(&TinyConfig { n, ..Default::default() }, &mut rng);

    // Preprocessing path: PJRT batch encode when artifacts are available.
    let bh = BhHash::sample(data.dim(), cfg.bits(), &mut rng);
    match chh::runtime::Runtime::open_default() {
        Ok(rt) => match chh::runtime::BatchEncoder::bilinear(&rt, "tiny") {
            Ok(enc) if data.dim() == 384 && cfg.bits() == 20 => {
                let t0 = std::time::Instant::now();
                match enc.encode_all(data.features(), &bh.pairs) {
                    Ok(codes) => println!(
                        "PJRT batch-encoded {} points in {:.2}s (tile {})",
                        codes.len(),
                        t0.elapsed().as_secs_f64(),
                        enc.tile_n()
                    ),
                    Err(e) => println!("PJRT encode failed ({e:#}); native path only"),
                }
            }
            _ => println!("artifacts missing or shape mismatch; native encode only"),
        },
        Err(e) => println!("PJRT unavailable ({e:#}); native encode only"),
    }

    let engine = AlEngine::new(&data, AlConfig::from_experiment(&cfg));
    let mut rows = Vec::new();
    for strat in ["random", "exhaustive", "bh", "lbh"] {
        let t0 = std::time::Instant::now();
        let res = engine.run_experiment(cfg.runs, cfg.max_classes, cfg.seed, |rng| match strat {
            "random" => Strategy::Random,
            "exhaustive" => Strategy::Exhaustive,
            "bh" => {
                let fam: Arc<dyn HashFamily> =
                    Arc::new(BhHash::sample(data.dim(), cfg.bits(), rng));
                let index =
                    Arc::new(HyperplaneIndex::build(fam.as_ref(), data.features(), cfg.radius()));
                Strategy::Hash { family: fam, index }
            }
            _ => {
                let m = cfg.lbh_m().min(1024);
                let sample = rng.sample_indices(data.len(), m);
                let refs = rng.sample_indices(data.len(), data.len().min(4000));
                let trainer =
                    LbhTrainer::new(LbhTrainConfig { bits: cfg.bits(), ..Default::default() });
                let (fam, _) = trainer.train(data.features(), &sample, &refs, rng);
                let fam: Arc<dyn HashFamily> = Arc::new(fam);
                let index =
                    Arc::new(HyperplaneIndex::build(fam.as_ref(), data.features(), cfg.radius()));
                Strategy::Hash { family: fam, index }
            }
        });
        let final_map = res.map_curve.last().map(|&(_, m)| m).unwrap_or(0.0);
        let mean_margin: f64 =
            res.margin_curve.iter().sum::<f64>() / res.margin_curve.len().max(1) as f64;
        rows.push(vec![
            res.strategy.clone(),
            format!("{final_map:.4}"),
            format!("{mean_margin:.5}"),
            format!("{:.1}s select", res.select_secs),
            format!("{:.1}s total", t0.elapsed().as_secs_f64()),
        ]);
    }
    chh::report::print_rows(
        "Fig 4 summary (tiny1m-like)",
        &["strategy", "final MAP", "mean margin", "select time", "wall"],
        &rows,
    );
}
