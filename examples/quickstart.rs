//! Quickstart: the 60-second tour of compact hyperplane hashing.
//!
//! 1. synthesize a Tiny-1M-like dataset;
//! 2. train LBH bilinear hash functions (§4 of the paper);
//! 3. build the single compact hash table;
//! 4. query with an SVM-style hyperplane and compare against randomized
//!    BH-Hash and the exhaustive scan.
//!
//! Run: `cargo run --release --example quickstart`

use std::time::Instant;

use chh::data::{tiny1m_like, TinyConfig};
use chh::hash::BhHash;
use chh::lbh::{LbhTrainConfig, LbhTrainer};
use chh::linalg::{margin_feat, nrm2};
use chh::rng::Rng;
use chh::svm::{LinearSvm, SvmConfig};
use chh::table::HyperplaneIndex;

fn main() {
    let mut rng = Rng::seed_from_u64(2012);

    // ── 1. data ──────────────────────────────────────────────────────
    let cfg = TinyConfig { n: 20_000, d: 128, ..Default::default() };
    println!("generating tiny1m-like dataset: n={} d={}", cfg.n, cfg.d);
    let data = tiny1m_like(&cfg, &mut rng);

    // ── 2. train LBH (k = 16 bits from m = 512 samples) ─────────────
    let k = 16;
    let t0 = Instant::now();
    let sample = rng.sample_indices(data.len(), 512);
    let reference = rng.sample_indices(data.len(), 4000);
    let trainer = LbhTrainer::new(LbhTrainConfig { bits: k, ..Default::default() });
    let (lbh, stats) = trainer.train(data.features(), &sample, &reference, &mut rng);
    println!(
        "trained {k}-bit LBH in {:.2}s (thresholds t1={:.3} t2={:.3})",
        t0.elapsed().as_secs_f64(),
        stats.t1,
        stats.t2
    );

    // ── 3. single compact hash table, Hamming radius 3 ───────────────
    let t1 = Instant::now();
    let index = HyperplaneIndex::build(&lbh, data.features(), 3);
    println!(
        "indexed {} points into {} buckets in {:.2}s ({} bytes)",
        index.len(),
        index.bucket_count(),
        t1.elapsed().as_secs_f64(),
        index.memory_bytes()
    );

    // a randomized BH baseline with the same code budget
    let bh = BhHash::sample(data.dim(), k, &mut rng);
    let index_bh = HyperplaneIndex::build(&bh, data.features(), 3);

    // ── 4. hyperplane query: an actual SVM decision boundary ────────
    let labeled = rng.sample_indices(data.len(), 600);
    let y: Vec<f32> =
        labeled.iter().map(|&i| if data.labels()[i] == 0 { 1.0 } else { -1.0 }).collect();
    let mut svm = LinearSvm::new(data.dim());
    svm.train(data.features(), &labeled, &y, &SvmConfig::default());
    let w = svm.w.clone();

    let tq = Instant::now();
    let hit = index.query(&lbh, &w, data.features());
    let t_hash = tq.elapsed();
    let tq = Instant::now();
    let hit_bh = index_bh.query(&bh, &w, data.features());
    let t_bh = tq.elapsed();

    // exhaustive ground truth
    let tq = Instant::now();
    let wn = nrm2(&w);
    let best_exh = (0..data.len())
        .map(|i| margin_feat(data.features().row(i), &w, wn))
        .fold(f32::INFINITY, f32::min);
    let t_exh = tq.elapsed();

    println!("\nquery: one-vs-all SVM hyperplane for class 0");
    println!(
        "  LBH-Hash   : margin {:.5}  ({} candidates, {:?})",
        hit.best.map(|(_, m)| m).unwrap_or(f32::NAN),
        hit.scanned,
        t_hash
    );
    println!(
        "  BH-Hash    : margin {:.5}  ({} candidates, {:?})",
        hit_bh.best.map(|(_, m)| m).unwrap_or(f32::NAN),
        hit_bh.scanned,
        t_bh
    );
    println!("  exhaustive : margin {best_exh:.5}  ({} points, {t_exh:?})", data.len());
    println!(
        "\nhash probes scanned {:.2}% of the database at {:.0}x lower query latency",
        100.0 * hit.scanned as f64 / data.len() as f64,
        t_exh.as_secs_f64() / t_hash.as_secs_f64().max(1e-9)
    );
}
