//! Online serving demo: dynamic sharded index + fan-out router.
//!
//! Plays out the deployment the static `serve_hyperplane` example can't:
//! points arrive and retire *while* hyperplane queries are being served.
//! An ingest thread streams new points in and retires old ones (50/50
//! churn); the query loop meanwhile emulates an active-learning consumer
//! that labels (and therefore removes) each returned candidate.
//!
//! Run: `cargo run --release --example online_serving`

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use chh::coordinator::{OnlineRouter, QueryRequest};
use chh::data::{tiny1m_like, TinyConfig};
use chh::hash::{BhHash, HashFamily};
use chh::online::{QueryBudget, ShardedIndex};
use chh::rng::Rng;

fn main() {
    let mut rng = Rng::seed_from_u64(7);
    let n = 40_000;
    let k = 18;
    let radius = 3;
    let shards = 8;
    println!("online_serving: n={n} d=128 k={k} r={radius} shards={shards}");
    let data = tiny1m_like(&TinyConfig { n, d: 128, ..Default::default() }, &mut rng);
    let family: Arc<dyn HashFamily> = Arc::new(BhHash::sample(data.dim(), k, &mut rng));

    // warm the index with half the stream
    let index = Arc::new(ShardedIndex::new(k, radius, shards));
    let warm = n / 2;
    let t0 = Instant::now();
    for i in 0..warm {
        index.insert_point(family.as_ref(), i as u32, data.features().row(i));
    }
    index.compact();
    println!(
        "warm load: {warm} points in {:.2}s, {} live, memory ~ {:.1} MB",
        t0.elapsed().as_secs_f64(),
        index.len(),
        index.memory_bytes() as f64 / 1e6
    );

    let feats = Arc::new(data.features().clone());
    let budget = QueryBudget::new(1024, 64); // best-first: ~1/6 of the r=3 ball
    let router = OnlineRouter::new(family.clone(), index.clone(), feats.clone(), 3, 64, budget);

    // ingest thread: stream the second half in, retire old points 50/50
    let ingest_idx = index.clone();
    let ingest_fam = family.clone();
    let ingest_feats = feats.clone();
    let ingest = std::thread::spawn(move || {
        let mut rng = Rng::seed_from_u64(99);
        let mut next = warm;
        let mut ops = 0usize;
        while next < n {
            ingest_idx.insert_point(ingest_fam.as_ref(), next as u32, ingest_feats.row(next));
            next += 1;
            ingest_idx.remove(rng.below(next) as u32);
            ops += 2;
        }
        ops
    });

    // query loop: an AL consumer that "labels" (removes) what it selects
    let iters = 40;
    let batch = 10;
    let t0 = Instant::now();
    let mut labeled = 0usize;
    for _ in 0..iters {
        let reqs: Vec<QueryRequest> = (0..batch)
            .map(|_| QueryRequest {
                w: chh::testing::unit_vec(&mut rng, data.dim()),
                exclude: None,
            })
            .collect();
        for resp in router.submit_batch(reqs) {
            if let Some((id, _margin)) = resp.hit.best {
                if index.remove(id as u32) {
                    labeled += 1; // labeled points leave the pool
                }
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let ops = ingest.join().expect("ingest thread");
    let st = router.stats();
    let served = iters * batch;
    println!("\nserved {served} queries while ingesting ({ops} churn ops) in {secs:.3}s");
    println!("  throughput : {:.0} queries/s", served as f64 / secs);
    println!(
        "  latency    : mean {:.1}µs  p50 {:.1}µs  p95 {:.1}µs",
        st.latency_mean() * 1e6,
        st.latency_p50() * 1e6,
        st.latency_p95() * 1e6
    );
    println!(
        "  labeled    : {labeled}   empty lookups {}   candidates/query {:.1}",
        st.empty_lookups.load(Ordering::Relaxed),
        st.candidates_scanned.load(Ordering::Relaxed) as f64 / served as f64
    );
    println!(
        "  index      : {} live, epochs {:?}",
        index.len(),
        index.epochs()
    );
    router.shutdown();
}
