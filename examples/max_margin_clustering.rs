//! Extension application (paper §1: "...applicable to a large spectrum of
//! machine learning problems such as ... cutting-plane based maximum
//! margin clustering"): a simple alternating max-margin clustering loop
//! where each iteration's most-violated points are found with hyperplane
//! hashing instead of a full scan.
//!
//! The loop: (1) initialize labels from a random hyperplane; (2) train an
//! SVM on the current labels; (3) use the hyperplane index to pull the
//! points nearest the boundary; (4) flip the labels of boundary points
//! toward the side with more margin; repeat. Hashing makes step (3)
//! sub-linear — the same speedup mechanism as in active learning.
//!
//! Run: `cargo run --release --example max_margin_clustering`

use chh::data::{test_blobs, FeatureStore};
use chh::hash::{BhHash, HashFamily};
use chh::linalg::nrm2;
use chh::rng::Rng;
use chh::svm::{LinearSvm, SvmConfig};
use chh::table::HyperplaneIndex;

fn cluster_agreement(pred: &[f32], truth: &[u16]) -> f64 {
    // best of the two label permutations
    let n = pred.len();
    let agree: usize = pred
        .iter()
        .zip(truth.iter())
        .filter(|(&p, &t)| (p > 0.0) == (t == 0))
        .count();
    agree.max(n - agree) as f64 / n as f64
}

fn main() {
    let mut rng = Rng::seed_from_u64(99);
    let n = 10_000;
    let d = 64;
    println!("max-margin clustering demo: n={n} d={d}, 2 latent clusters");
    let data = test_blobs(n, d, 2, &mut rng);
    let feats: &FeatureStore = data.features();

    // hash index for boundary-point retrieval
    let fam = BhHash::sample(d, 14, &mut rng);
    let index = HyperplaneIndex::build(&fam, feats, 3);

    // init: random hyperplane labeling
    let w0 = chh::testing::unit_vec(&mut rng, d);
    let mut y: Vec<f32> =
        (0..n).map(|i| if feats.row(i).dot(&w0) >= 0.0 { 1.0 } else { -1.0 }).collect();
    let idx: Vec<usize> = (0..n).collect();
    let cfg = SvmConfig { c: 0.1, ..Default::default() };

    println!("initial agreement: {:.3}", cluster_agreement(&y, data.labels()));
    let mut svm = LinearSvm::new(d);
    for round in 0..8 {
        svm = LinearSvm::new(d);
        svm.train(feats, &idx, &y, &cfg);
        // cutting-plane-ish step: find boundary points via hashing and
        // re-assign them to the side of their sign
        let mut flipped = 0usize;
        let hit = index.query(&fam, &svm.w, feats);
        let scanned = hit.scanned.max(1);
        // pull a boundary neighborhood: all ball candidates
        let lookup = fam.encode_query(&svm.w);
        let mut cand = Vec::new();
        index.candidates_into(lookup, usize::MAX, &mut cand);
        for &i in &cand {
            let i = i as usize;
            let s = feats.row(i).dot(&svm.w);
            let want = if s >= 0.0 { 1.0 } else { -1.0 };
            if y[i] != want {
                y[i] = want;
                flipped += 1;
            }
        }
        let margin_sum: f32 = cand
            .iter()
            .map(|&i| feats.row(i as usize).dot(&svm.w).abs())
            .sum::<f32>()
            / nrm2(&svm.w).max(1e-9);
        println!(
            "round {round}: boundary candidates {:>5} (scanned {scanned:>5}), flipped {flipped:>4}, \
             mean boundary margin {:.4}, agreement {:.3}",
            cand.len(),
            margin_sum / cand.len().max(1) as f32,
            cluster_agreement(&y, data.labels())
        );
    }
    let final_agreement = cluster_agreement(&y, data.labels());
    println!("\nfinal cluster agreement vs latent blobs: {final_agreement:.3}");
    let obj = svm.primal_objective(feats, &idx, &y, &cfg);
    println!("final SVM primal objective: {obj:.2}");
}
