//! Serving demo: the hyperplane-query router under a synthetic query
//! stream, reporting throughput and latency percentiles — the systems-y
//! face of the paper's constant-time single-table lookup claim.
//!
//! Emulates an active-learning fleet: every "iteration" submits a batch of
//! one-vs-all SVM hyperplanes (10 classes) with a shared exclusion set
//! that grows as labels arrive, exactly like `active::AlEngine` would.
//!
//! Run: `cargo run --release --example serve_hyperplane`

use std::collections::HashSet;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use chh::coordinator::{QueryRequest, Router};
use chh::data::{tiny1m_like, TinyConfig};
use chh::hash::{BhHash, HashFamily};
use chh::lbh::{LbhTrainConfig, LbhTrainer};
use chh::rng::Rng;
use chh::table::HyperplaneIndex;

fn main() {
    let mut rng = Rng::seed_from_u64(7);
    let n = 50_000;
    let k = 18;
    let radius = 3;
    println!("building index: n={n} d=128 k={k} radius={radius}");
    let data = tiny1m_like(&TinyConfig { n, d: 128, ..Default::default() }, &mut rng);

    // learned hash for serving (falls back to BH if training is disabled)
    let use_lbh = !std::env::args().any(|a| a == "--bh");
    let family: Arc<dyn HashFamily> = if use_lbh {
        let sample = rng.sample_indices(n, 512);
        let refs = rng.sample_indices(n, 4000);
        let (f, _) = LbhTrainer::new(LbhTrainConfig { bits: k, ..Default::default() })
            .train(data.features(), &sample, &refs, &mut rng);
        Arc::new(f)
    } else {
        Arc::new(BhHash::sample(data.dim(), k, &mut rng))
    };
    let t0 = Instant::now();
    let index = Arc::new(HyperplaneIndex::build(family.as_ref(), data.features(), radius));
    println!(
        "table built in {:.2}s: {} buckets, probe volume {}",
        t0.elapsed().as_secs_f64(),
        index.bucket_count(),
        index.probe_volume()
    );
    let feats = Arc::new(data.features().clone());
    let router = Router::new(family, index, feats, 2, 64);

    // synthetic AL fleet: 50 iterations × 10 hyperplanes
    let classes = 10;
    let iters = 50;
    let mut labeled: HashSet<usize> = (0..500).collect();
    let t0 = Instant::now();
    let mut answered = 0usize;
    for _it in 0..iters {
        let exclude = Arc::new(labeled.clone());
        let reqs: Vec<QueryRequest> = (0..classes)
            .map(|_| QueryRequest {
                w: chh::testing::unit_vec(&mut rng, data.dim()),
                exclude: Some(exclude.clone()),
            })
            .collect();
        for resp in router.submit_batch(reqs) {
            answered += 1;
            if let Some((idx, _)) = resp.hit.best {
                labeled.insert(idx); // "label" the selected point
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let st = router.stats();
    println!("\nserved {answered} hyperplane queries in {secs:.3}s");
    println!("  throughput : {:.0} queries/s", answered as f64 / secs);
    println!("  latency    : mean {:.1}µs  p50 {:.1}µs  p95 {:.1}µs",
        st.latency_mean() * 1e6,
        st.latency_p50() * 1e6,
        st.latency_p95() * 1e6
    );
    println!(
        "  empty balls: {} / {}   candidates/query: {:.1}",
        st.empty_lookups.load(Ordering::Relaxed),
        answered,
        st.candidates_scanned.load(Ordering::Relaxed) as f64 / answered as f64
    );
    router.shutdown();
}
