//! End-to-end driver: SVM active learning on the 20-Newsgroups-like corpus
//! (paper §5, Fig. 3). Runs all six selection strategies and prints the
//! MAP learning curves, selected-margin curves and nonempty-lookup counts.
//!
//! Default scale is laptop-friendly; pass `--full` for the paper's setup
//! (n=18,846, 20 classes, 300 iterations, 5 runs).
//!
//! Run: `cargo run --release --example active_learning_news [-- --full]`

use std::sync::Arc;

use chh::active::{AlConfig, AlEngine, Strategy};
use chh::config::{DatasetProfile, ExperimentConfig};
use chh::data::{newsgroups_like, NewsConfig};
use chh::hash::{AhHash, BhHash, EhHash, HashFamily};
use chh::lbh::{LbhTrainConfig, LbhTrainer};
use chh::report::{ascii_plot, Series};
use chh::rng::Rng;
use chh::table::HyperplaneIndex;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let mut cfg = ExperimentConfig::for_profile(DatasetProfile::News);
    if !full {
        cfg.n = 4000;
        cfg.al_iters = 100;
        cfg.runs = 2;
        cfg.max_classes = Some(4);
    }
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let news = NewsConfig {
        n: cfg.n,
        vocab: cfg.profile.dim(),
        classes: if full { 20 } else { 8 },
        ..Default::default()
    };
    println!(
        "20NG-like corpus: n={} vocab={} classes={}  (k={} bits, radius {})",
        news.n,
        news.vocab,
        news.classes,
        cfg.bits(),
        cfg.radius()
    );
    let data = newsgroups_like(&news, &mut rng);
    let engine = AlEngine::new(&data, AlConfig::from_experiment(&cfg));

    let mut map_series = Vec::new();
    let mut rows = Vec::new();
    for strat in ["random", "exhaustive", "ah", "eh", "bh", "lbh"] {
        let t0 = std::time::Instant::now();
        let res = engine.run_experiment(cfg.runs, cfg.max_classes, cfg.seed, |rng| {
            build_strategy(strat, &cfg, &data, rng)
        });
        let final_map = res.map_curve.last().map(|&(_, m)| m).unwrap_or(0.0);
        let mean_margin: f64 =
            res.margin_curve.iter().sum::<f64>() / res.margin_curve.len().max(1) as f64;
        let nonempty: f64 = res.nonempty_per_class.iter().sum::<f64>()
            / res.nonempty_per_class.len().max(1) as f64;
        rows.push(vec![
            res.strategy.clone(),
            format!("{final_map:.4}"),
            format!("{mean_margin:.5}"),
            format!("{nonempty:.0}/{}", cfg.al_iters),
            format!("{:.1}s", t0.elapsed().as_secs_f64()),
        ]);
        let mut s = Series::new(&res.strategy);
        for &(it, m) in &res.map_curve {
            s.push(it as f64, m);
        }
        map_series.push(s);
    }
    chh::report::print_rows(
        "Fig 3 summary (20NG-like)",
        &["strategy", "final MAP", "mean margin", "nonempty/iters", "wall"],
        &rows,
    );
    println!("\n{}", ascii_plot("Fig 3(a): MAP learning curves", &map_series, 64, 16));
}

fn build_strategy(
    name: &str,
    cfg: &ExperimentConfig,
    data: &chh::data::Dataset,
    rng: &mut Rng,
) -> Strategy {
    let bits = cfg.bits();
    let radius = cfg.radius();
    match name {
        "random" => Strategy::Random,
        "exhaustive" => Strategy::Exhaustive,
        "ah" => {
            let fam: Arc<dyn HashFamily> = Arc::new(AhHash::sample(data.dim(), bits, rng));
            let index = Arc::new(HyperplaneIndex::build(fam.as_ref(), data.features(), radius));
            Strategy::Hash { family: fam, index }
        }
        "eh" => {
            let fam: Arc<dyn HashFamily> =
                Arc::new(EhHash::sampled(data.dim(), bits, 256, rng));
            let index = Arc::new(HyperplaneIndex::build(fam.as_ref(), data.features(), radius));
            Strategy::Hash { family: fam, index }
        }
        "bh" => {
            let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(data.dim(), bits, rng));
            let index = Arc::new(HyperplaneIndex::build(fam.as_ref(), data.features(), radius));
            Strategy::Hash { family: fam, index }
        }
        "lbh" => {
            let m = cfg.lbh_m();
            let sample = rng.sample_indices(data.len(), m);
            let reference = rng.sample_indices(data.len(), data.len().min(4000));
            let trainer = LbhTrainer::new(LbhTrainConfig { bits, ..Default::default() });
            let (fam, _) = trainer.train(data.features(), &sample, &reference, rng);
            let fam: Arc<dyn HashFamily> = Arc::new(fam);
            let index = Arc::new(HyperplaneIndex::build(fam.as_ref(), data.features(), radius));
            Strategy::Hash { family: fam, index }
        }
        other => panic!("unknown strategy {other}"),
    }
}
