#!/usr/bin/env python3
"""Bench regression gate: compare a fresh bench JSON against a committed
baseline and fail on >tolerance regressions of machine-portable metrics.

Absolute wall-clock (mean_s/p50_s/...) is machine-dependent and never
gated. What IS gated:

  * ``speedup`` records (batch_throughput): the serial/pooled or
    scalar/kernel ratio measured *within one run* on one machine. A
    ratio is portable — if the blocked encode kernel stops beating the
    scalar loop, the ratio collapses no matter how fast the runner is.
  * ``probe_sweep`` records (online_churn), matched on (probes, top):
    ``hits`` and ``cands_per_q`` are deterministic functions of the
    seeded workload — a hits drop or a candidate blow-up is a search
    quality/work regression, not noise.
  * ``bulk_load``/``churn`` records: deterministic counters
    (``inserts``, ``live``) must match the baseline within tolerance.

A baseline with no records is a bootstrap stub: the gate then only
checks the fresh run's shape (expected record kinds present and sane)
and exits 0, printing the values to seed the baseline from the CI
artifact (see benchmarks/README.md).

Usage: bench_gate.py --baseline <committed.json> --current <fresh.json>
       [--tolerance 0.20]
"""

import argparse
import json
import sys


def load(path):
    with open(path) as f:
        doc = json.load(f)
    if "records" not in doc or "bench" not in doc:
        sys.exit(f"{path}: not a JsonReport document")
    return doc


def ratio(rec):
    """Parse a speedup record's 'N.NNx' ratio."""
    s = rec.get("speedup", "")
    if not s.endswith("x"):
        sys.exit(f"speedup record {rec.get('path')!r}: bad ratio {s!r}")
    return float(s[:-1])


def by_kind(doc, kind):
    return [r for r in doc["records"] if r.get("kind") == kind]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--tolerance", type=float, default=0.20)
    args = ap.parse_args()
    base, cur = load(args.baseline), load(args.current)
    if base["bench"] != cur["bench"]:
        sys.exit(f"bench mismatch: baseline {base['bench']} vs current {cur['bench']}")
    tol = args.tolerance
    failures = []

    if not base["records"]:
        # bootstrap stub: shape-check the fresh run, print seed values
        kinds = {r.get("kind") for r in cur["records"] if "kind" in r}
        print(f"{base['bench']}: baseline is a bootstrap stub; "
              f"fresh run has kinds {sorted(kinds)}")
        for r in by_kind(cur, "speedup"):
            print(f"  speedup {r['path']}: {r['speedup']}")
        for r in by_kind(cur, "probe_sweep"):
            print(f"  probe_sweep probes={r['probes']} top={r['top']}: "
                  f"hits={r['hits']} cands_per_q={r['cands_per_q']}")
        print("seed the baseline from this artifact to arm the gate")
        return

    # ── speedup ratios ───────────────────────────────────────────────
    cur_speedups = {r["path"]: r for r in by_kind(cur, "speedup")}
    for b in by_kind(base, "speedup"):
        path = b["path"]
        c = cur_speedups.get(path)
        if c is None:
            failures.append(f"speedup row '{path}' missing from current run")
            continue
        want, got = ratio(b), ratio(c)
        floor = want * (1.0 - tol)
        status = "ok" if got >= floor else "REGRESSED"
        print(f"speedup {path}: baseline {want:.2f}x, current {got:.2f}x, "
              f"floor {floor:.2f}x — {status}")
        if got < floor:
            failures.append(
                f"speedup '{path}' regressed: {got:.2f}x < {floor:.2f}x "
                f"(baseline {want:.2f}x − {tol:.0%})")

    # ── deterministic workload counters ──────────────────────────────
    cur_sweeps = {(r["probes"], r["top"]): r for r in by_kind(cur, "probe_sweep")}
    for b in by_kind(base, "probe_sweep"):
        key = (b["probes"], b["top"])
        c = cur_sweeps.get(key)
        if c is None:
            failures.append(f"probe_sweep {key} missing from current run")
            continue
        if c["hits"] < b["hits"] * (1.0 - tol):
            failures.append(
                f"probe_sweep {key}: hits {c['hits']} < baseline {b['hits']} − {tol:.0%}")
        if c["cands_per_q"] > b["cands_per_q"] * (1.0 + tol):
            failures.append(
                f"probe_sweep {key}: cands_per_q {c['cands_per_q']} > "
                f"baseline {b['cands_per_q']} + {tol:.0%}")
        print(f"probe_sweep {key}: hits {c['hits']} (base {b['hits']}), "
              f"cands_per_q {c['cands_per_q']} (base {b['cands_per_q']})")
    for kind, fields in (("bulk_load", ["inserts"]), ("churn", ["live"])):
        bs, cs = by_kind(base, kind), by_kind(cur, kind)
        if bs and not cs:
            failures.append(f"{kind} record missing from current run")
        for b, c in zip(bs, cs):
            for f in fields:
                lo, hi = b[f] * (1.0 - tol), b[f] * (1.0 + tol)
                if not (lo <= c[f] <= hi):
                    failures.append(f"{kind}.{f}: {c[f]} outside [{lo:.0f}, {hi:.0f}]")

    if failures:
        print(f"\n{base['bench']}: {len(failures)} regression(s):", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print(f"{base['bench']}: gate passed")


if __name__ == "__main__":
    main()
